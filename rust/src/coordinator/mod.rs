//! L3 coordinator: a dispatcher/executor serving pipeline over the netlist.
//!
//! The paper's deployment story is a streaming accelerator core (II = 1)
//! fed by a host; this module is that host-side system, structured as a
//! two-stage pipeline so batch *formation* never serializes behind batch
//! *execution*:
//!
//! ```text
//! clients --submit--> [admission queue] --> dispatcher --> [work queue] --> executors 0..N-1
//!                      bounded,              owns the        bounded         run batches,
//!                      backpressure          receiver,       handoff         reply to clients
//!                                            forms batches
//! ```
//!
//! A single **dispatcher** thread owns the admission receiver outright, so
//! no thread ever holds a lock across a batch-collection wait. It forms
//! batches with [`batcher::collect`], which consults
//! [`batcher::Policy::decide`] for every dispatch decision — fill to
//! `max_batch`, or flush once the *oldest request* (measured from its
//! submission, not from when collection started) has waited `max_wait`.
//! Formed [`batcher::Batch`]es travel over a bounded work channel to the
//! **executor** pool: while one batch executes, the dispatcher is already
//! forming the next, and N executors run N batches concurrently. Tokio is
//! not available offline; std threads + channels are the right tool for
//! these CPU-bound microsecond batches anyway.
//!
//! Executors run on a [`Backend`]: the default is the compiled flat
//! program of [`crate::engine`] (batch-major, hot-swap aware via
//! [`ProgramCell`], cross-checked against [`crate::sim`] in debug builds);
//! the netlist-walking interpreter remains selectable for debugging and
//! A/B benchmarking.
//!
//! Shutdown is graceful: [`Service::shutdown`] disconnects admission, the
//! dispatcher drains and dispatches what was already admitted, executors
//! finish and exit, and any later `submit*` call fails fast with
//! [`SubmitError::Stopped`] instead of spinning.

pub mod batcher;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{Executor, ProgramCell};
use crate::netlist::hotswap::NetlistCell;
use crate::netlist::Netlist;
use crate::sim;
use crate::util::Reservoir;

use batcher::{Batch, Policy, Timestamped};

/// Retained latency samples: quantiles stay approximately correct under
/// sustained load at O(1) memory (the previous unbounded summary retained
/// every sample of every request forever).
const LATENCY_RESERVOIR: usize = 4096;

/// One inference request (input codes).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub codes: Vec<u32>,
    pub submitted: Instant,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub sums: Vec<i64>,
    /// Queue + batch + execute time.
    pub latency: Duration,
}

struct Pending {
    req: Request,
    reply: SyncSender<Response>,
}

impl Timestamped for Pending {
    fn submitted(&self) -> Instant {
        self.req.submitted
    }
}

/// Why admission failed. Callers must distinguish retryable backpressure
/// from terminal conditions — retrying a stopped service or a malformed
/// request spins forever.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full; retrying later can succeed.
    Backpressure,
    /// Service shut down; no retry will ever succeed.
    Stopped,
    /// Malformed request (wrong input width); no retry will ever succeed.
    Invalid(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "admission queue full (backpressure)"),
            SubmitError::Stopped => write!(f, "service stopped"),
            SubmitError::Invalid(msg) => write!(f, "invalid request: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Which executor the worker pool runs batches on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Flat compiled program ([`crate::engine`]): batch-major table scans.
    /// The serving default.
    #[default]
    Compiled,
    /// Netlist-graph interpreter ([`crate::sim::Evaluator`]): per-sample
    /// walk. Kept for debugging and as the A/B baseline.
    Interpreted,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "compiled" | "engine" => Some(Backend::Compiled),
            "interpreted" | "sim" => Some(Backend::Interpreted),
            _ => None,
        }
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceCfg {
    /// Executor threads; batch formation always uses one extra dispatcher
    /// thread (none of either is spawned when `workers == 0`).
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bounded admission queue (backpressure).
    pub queue_depth: usize,
    pub backend: Backend,
    /// Artificial per-batch execution delay. Zero in production; test and
    /// bench instrumentation that stretches execution so pipeline overlap
    /// is observable on microsecond workloads.
    pub exec_delay: Duration,
}

impl Default for ServiceCfg {
    fn default() -> Self {
        ServiceCfg {
            workers: 4,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            backend: Backend::Compiled,
            exec_delay: Duration::ZERO,
        }
    }
}

/// Aggregated service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub completed: u64,
    pub rejected: u64,
    /// Admitted but never executed: the request's width stopped matching
    /// the model snapshot (admission raced a `replace_model`). The client
    /// observes a closed reply channel.
    pub dropped: u64,
    /// Batches formed by the dispatcher (counted at formation, so under
    /// load this runs ahead of execution — the pipeline is visible here).
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    /// Samples per second over the service lifetime.
    pub throughput_rps: f64,
    /// Fused LUT ops executed (samples x ops-per-sample).
    pub fused_ops: u64,
    /// Fused LUT ops per second over the service lifetime — the single
    /// comparable perf number across backends, batch sizes and PRs.
    pub throughput_ops: f64,
    /// Largest executor scratch footprint observed (bytes).
    pub scratch_bytes: u64,
}

struct Shared {
    /// Bounded reservoir — O(1) memory no matter how long the service runs.
    latencies: Mutex<Reservoir>,
    completed: AtomicU64,
    rejected: AtomicU64,
    dropped: AtomicU64,
    batches: AtomicU64,
    /// Total requests across all formed batches (mean batch = this / batches).
    batched: AtomicU64,
    /// Fused LUT ops executed (valid samples x ops-per-sample), counted at
    /// execution: the backend-independent work unit that makes perf numbers
    /// comparable across PRs.
    fused_ops: AtomicU64,
    /// Largest executor scratch footprint observed, bytes (feature-major
    /// planes grow to the biggest batch seen and never shrink).
    scratch: AtomicU64,
}

/// Batched inference service over a netlist.
pub struct Service {
    /// Admission sender; taken (→ `None`) by [`Service::shutdown`], which
    /// disconnects the dispatcher. RwLock so concurrent submitters share a
    /// read lock on the hot path.
    tx: RwLock<Option<SyncSender<Pending>>>,
    /// With zero workers there is no dispatcher to own the admission
    /// receiver; parked here so the queue stays connected and backpressure
    /// is observable without anything draining it.
    rx_parked: Mutex<Option<Receiver<Pending>>>,
    /// Hot-swappable model handle (paper §6: online LUT updates).
    cell: Arc<NetlistCell>,
    shared: Arc<Shared>,
    next_id: AtomicU64,
    started: Instant,
    /// Dispatcher + executors; drained on shutdown.
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    cfg: ServiceCfg,
}

impl Service {
    pub fn start(net: Arc<Netlist>, cfg: ServiceCfg) -> Service {
        Self::start_swappable(Arc::new(NetlistCell::new(net)), cfg)
    }

    /// Start over a swappable cell: edge tables (or the whole model) can be
    /// replaced while serving; in-flight batches finish on their snapshot.
    pub fn start_swappable(cell: Arc<NetlistCell>, cfg: ServiceCfg) -> Service {
        let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
        let shared = Arc::new(Shared {
            latencies: Mutex::new(Reservoir::new(LATENCY_RESERVOIR)),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched: AtomicU64::new(0),
            fused_ops: AtomicU64::new(0),
            scratch: AtomicU64::new(0),
        });
        let mut threads = Vec::with_capacity(cfg.workers + 1);
        let mut rx_parked = None;
        if cfg.workers == 0 {
            rx_parked = Some(rx);
        } else {
            // backend resources: the compiled path shares one program cache
            // (compiled once here, recompiled lazily after hot-swaps); the
            // interpreted path never pays for compilation
            let exec_backend = match cfg.backend {
                Backend::Compiled => {
                    WorkerBackend::Compiled(Arc::new(ProgramCell::new(Arc::clone(&cell))))
                }
                Backend::Interpreted => WorkerBackend::Interpreted(Arc::clone(&cell)),
            };
            // handoff depth = workers: every executor can be running one
            // batch with another staged before the dispatcher blocks
            let (work_tx, work_rx) = sync_channel::<Batch<Pending>>(cfg.workers);
            let work_rx = Arc::new(Mutex::new(work_rx));
            for w in 0..cfg.workers {
                let work_rx = Arc::clone(&work_rx);
                let backend = exec_backend.clone();
                let shared = Arc::clone(&shared);
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("kanele-exec-{w}"))
                        .spawn(move || executor_loop(work_rx, backend, shared, cfg))
                        .expect("spawn executor"),
                );
            }
            let policy = Policy { max_batch: cfg.max_batch, max_wait: cfg.max_wait };
            let shared_d = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("kanele-dispatch".into())
                    .spawn(move || dispatcher_loop(rx, work_tx, policy, shared_d))
                    .expect("spawn dispatcher"),
            );
        }
        Service {
            tx: RwLock::new(Some(tx)),
            rx_parked: Mutex::new(rx_parked),
            cell,
            shared,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            threads: Mutex::new(threads),
            cfg,
        }
    }

    /// Hot-swap one edge table while serving (paper §6 future work).
    pub fn swap_edge(&self, layer: usize, q: usize, p: usize, table: Vec<i64>) -> Result<()> {
        self.cell.swap_edge(layer, q, p, table)
    }

    /// Replace the whole model while serving.
    pub fn replace_model(&self, net: Arc<Netlist>) {
        self.cell.replace(net);
    }

    /// Reject malformed requests at admission: a wrong-width row inside a
    /// compiled batch would otherwise shift every later sample in the
    /// batch-major input plane (cross-request corruption).
    fn check_width(&self, codes: &[u32]) -> Result<(), SubmitError> {
        let want = self.cell.input_width();
        if codes.len() != want {
            return Err(SubmitError::Invalid(format!(
                "request width {} != model input width {want}",
                codes.len()
            )));
        }
        Ok(())
    }

    /// Submit a request; the returned receiver yields the response. Fails
    /// fast with a typed [`SubmitError`]: wrong width and shutdown are
    /// terminal, a full admission queue is retryable backpressure.
    pub fn submit(&self, codes: Vec<u32>) -> Result<Receiver<Response>, SubmitError> {
        // validated on every call: a concurrent replace_model can change
        // the expected width between retries
        self.check_width(&codes)?;
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            codes,
            submitted: Instant::now(),
        };
        let tx = self.tx.read().unwrap();
        let Some(tx) = tx.as_ref() else {
            return Err(SubmitError::Stopped);
        };
        match tx.try_send(Pending { req, reply: reply_tx }) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Backpressure)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Stopped),
        }
    }

    /// Submit with blocking retry (used by the closed-loop example). Only
    /// backpressure retries; malformed requests and a stopped service
    /// return the error immediately instead of spinning forever.
    pub fn submit_blocking(&self, codes: Vec<u32>) -> Result<Response> {
        loop {
            match self.submit(codes.clone()) {
                Ok(rx) => {
                    return rx.recv().context("request dropped (model swap or shutdown mid-flight)")
                }
                Err(SubmitError::Backpressure) => std::thread::sleep(Duration::from_micros(20)),
                Err(e) => return Err(e.into()),
            }
        }
    }

    pub fn stats(&self) -> ServiceStats {
        let qs = self.shared.latencies.lock().unwrap().quantiles(&[0.5, 0.99]);
        let (p50, p99) = (qs[0], qs[1]);
        let completed = self.shared.completed.load(Ordering::Relaxed);
        let batches = self.shared.batches.load(Ordering::Relaxed);
        let batched = self.shared.batched.load(Ordering::Relaxed);
        let fused_ops = self.shared.fused_ops.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64();
        ServiceStats {
            completed,
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            dropped: self.shared.dropped.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { batched as f64 / batches as f64 },
            latency_p50_us: p50 * 1e6,
            latency_p99_us: p99 * 1e6,
            throughput_rps: completed as f64 / elapsed,
            fused_ops,
            throughput_ops: fused_ops as f64 / elapsed,
            scratch_bytes: self.shared.scratch.load(Ordering::Relaxed),
        }
    }

    pub fn cfg(&self) -> ServiceCfg {
        self.cfg
    }

    /// Stop the pipeline and join its threads. Graceful: everything already
    /// admitted is dispatched and executed first. Idempotent, and callable
    /// through a shared reference (e.g. an `Arc<Service>` while other
    /// clients still hold clones — their next `submit*` fails fast with
    /// [`SubmitError::Stopped`]).
    pub fn shutdown(&self) {
        // disconnect admission: the dispatcher drains the queue, forwards
        // the final partial batch, then hangs up the work channel, which
        // winds down the executors
        self.tx.write().unwrap().take();
        self.rx_parked.lock().unwrap().take();
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-executor execution resources, fixed at service start.
#[derive(Clone)]
enum WorkerBackend {
    Compiled(Arc<ProgramCell>),
    Interpreted(Arc<NetlistCell>),
}

/// Shared handoff end of the dispatcher → executor work channel.
type WorkQueue = Arc<Mutex<Receiver<Batch<Pending>>>>;

/// Pipeline stage 1 — sole owner of the admission receiver. Every dispatch
/// decision comes from [`batcher::Policy::decide`] via
/// [`batcher::collect`]; formed batches are handed downstream over the
/// bounded work channel. Exits when admission is disconnected and drained.
fn dispatcher_loop(
    rx: Receiver<Pending>,
    work_tx: SyncSender<Batch<Pending>>,
    policy: Policy,
    shared: Arc<Shared>,
) {
    while let Some(batch) = batcher::collect(&rx, &policy) {
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.batched.fetch_add(batch.len() as u64, Ordering::Relaxed);
        if work_tx.send(batch).is_err() {
            return; // executors gone; nothing left to feed
        }
    }
    // dropping work_tx here lets executors finish queued batches and exit
}

/// Pipeline stage 2 — pull formed batches off the work queue and run them.
/// An *idle* executor does hold the work-receiver lock while blocked in
/// `recv`, but releases it the moment a batch arrives (before executing),
/// so batch *formation* never waits on executors, executions overlap
/// freely, and only executors with nothing to do queue on the mutex —
/// unlike the old design, no lock is held across a batch-collection wait.
fn executor_loop(work_rx: WorkQueue, backend: WorkerBackend, shared: Arc<Shared>, cfg: ServiceCfg) {
    // per-executor scratch, reused across batches and hot-swaps; sized so
    // the compiled hot path never allocates planes after startup. `flat` is
    // the caller-owned output plane of `run_batch_into`: one flat buffer
    // per executor instead of a Vec<Vec<i64>> per batch.
    let mut exec = match &backend {
        WorkerBackend::Compiled(programs) => {
            Executor::with_capacity(&programs.load().1, cfg.max_batch)
        }
        WorkerBackend::Interpreted(_) => Executor::new(),
    };
    let mut flat: Vec<i64> = Vec::new();
    loop {
        let batch = match work_rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return, // dispatcher hung up and the queue is drained
        };
        execute_batch(batch, &backend, &mut exec, &mut flat, &shared, &cfg);
    }
}

/// Run one batch on the backend and complete its requests.
fn execute_batch(
    batch: Batch<Pending>,
    backend: &WorkerBackend,
    exec: &mut Executor,
    flat: &mut Vec<i64>,
    shared: &Shared,
    cfg: &ServiceCfg,
) {
    let items = batch.items;
    // batch-consistent snapshot: a concurrent hot-swap applies to the
    // NEXT batch, never mid-batch (PR-region semantics). Requests whose
    // width no longer matches the snapshot (admission raced a
    // whole-model replace) yield None: their reply channel is dropped
    // instead of corrupting co-batched samples.
    let outputs: Vec<Option<Vec<i64>>> = match backend {
        WorkerBackend::Compiled(programs) => {
            let (net, prog) = programs.load();
            let d_in = prog.d_in();
            let d_out = prog.d_out();
            let rows: Vec<&[u32]> = items
                .iter()
                .map(|p| p.req.codes.as_slice())
                .filter(|r| r.len() == d_in)
                .collect();
            // whole batch into the reused flat plane: the engine allocates
            // nothing; per-request sums are sliced out at completion
            exec.run_batch_into(&prog, &rows, flat);
            shared
                .fused_ops
                .fetch_add((rows.len() * prog.n_ops()) as u64, Ordering::Relaxed);
            shared.scratch.fetch_max(exec.scratch_bytes() as u64, Ordering::Relaxed);
            // checked invariant: the compiled program IS the netlist
            if cfg!(debug_assertions) {
                let mut ev = sim::Evaluator::new(&net);
                for (i, row) in rows.iter().enumerate() {
                    debug_assert_eq!(
                        ev.eval(row),
                        &flat[i * d_out..(i + 1) * d_out],
                        "engine/sim divergence"
                    );
                }
            }
            let mut next = 0usize;
            items
                .iter()
                .map(|p| {
                    (p.req.codes.len() == d_in).then(|| {
                        let sums = flat[next * d_out..(next + 1) * d_out].to_vec();
                        next += 1;
                        sums
                    })
                })
                .collect()
        }
        WorkerBackend::Interpreted(cell) => {
            let net = cell.load();
            let d_in = net.input_width();
            let ops_per_sample = net.n_luts() as u64;
            let mut ev = sim::Evaluator::new(&net);
            let mut valid = 0u64;
            let outs: Vec<Option<Vec<i64>>> = items
                .iter()
                .map(|p| {
                    (p.req.codes.len() == d_in).then(|| {
                        valid += 1;
                        ev.eval(&p.req.codes).to_vec()
                    })
                })
                .collect();
            shared.fused_ops.fetch_add(valid * ops_per_sample, Ordering::Relaxed);
            outs
        }
    };
    if !cfg.exec_delay.is_zero() {
        std::thread::sleep(cfg.exec_delay);
    }
    let mut dropped = 0u64;
    let mut done: Vec<(Pending, Vec<i64>, Duration)> = Vec::with_capacity(items.len());
    for (p, sums) in items.into_iter().zip(outputs) {
        match sums {
            Some(sums) => {
                let latency = p.req.submitted.elapsed();
                done.push((p, sums, latency));
            }
            // client sees RecvError on its reply channel
            None => dropped += 1,
        }
    }
    if dropped > 0 {
        shared.dropped.fetch_add(dropped, Ordering::Relaxed);
    }
    if !done.is_empty() {
        // one lock acquisition for the whole batch, not one per response
        {
            let mut lat = shared.latencies.lock().unwrap();
            for (_, _, latency) in &done {
                lat.push(latency.as_secs_f64());
            }
        }
        // publish counts before replying so a client holding its response
        // always observes itself in `completed`
        shared.completed.fetch_add(done.len() as u64, Ordering::Relaxed);
        for (p, sums, latency) in done {
            let _ = p.reply.send(Response { id: p.req.id, sums, latency });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::synthetic;
    use crate::lut;
    use crate::util::Rng;

    fn service(cfg: ServiceCfg) -> (Arc<Netlist>, Service) {
        let ck = synthetic(&[4, 3, 2], &[4, 5, 6], 2024);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(Arc::clone(&net), cfg);
        (net, svc)
    }

    #[test]
    fn both_backends_match_direct_eval() {
        for backend in [Backend::Compiled, Backend::Interpreted] {
            let (net, svc) = service(ServiceCfg { backend, ..Default::default() });
            let mut rng = Rng::new(42);
            let mut pending = Vec::new();
            let mut want = Vec::new();
            for _ in 0..100 {
                let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                want.push(sim::eval(&net, &codes));
                pending.push(svc.submit(codes).unwrap());
            }
            for (rx, w) in pending.into_iter().zip(want) {
                assert_eq!(rx.recv().unwrap().sums, w, "{backend:?}");
            }
            // both backends count the same backend-independent work unit
            assert_eq!(svc.stats().fused_ops, 100 * net.n_luts() as u64, "{backend:?}");
            svc.shutdown();
        }
    }

    #[test]
    fn responses_match_direct_eval() {
        let (net, svc) = service(ServiceCfg::default());
        let mut rng = Rng::new(1);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for _ in 0..200 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            want.push(sim::eval(&net, &codes));
            pending.push(svc.submit(codes).unwrap());
        }
        for (rx, w) in pending.into_iter().zip(want) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.sums, w);
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 200);
        assert!(stats.batches >= 1);
        // ops accounting: every completed sample ran the whole program once
        assert_eq!(stats.fused_ops, 200 * net.n_luts() as u64);
        assert!(stats.throughput_ops > 0.0);
        // the compiled backend publishes its feature-major scratch footprint
        assert!(stats.scratch_bytes > 0);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let (net, svc) = service(ServiceCfg { workers: 4, ..Default::default() });
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let svc = Arc::clone(&svc);
            let net = Arc::clone(&net);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..50 {
                    let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                    let want = sim::eval(&net, &codes);
                    let got = svc.submit_blocking(codes).unwrap();
                    assert_eq!(got.sums, want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(Arc::try_unwrap(svc).ok().unwrap().stats().completed, 400);
    }

    #[test]
    fn wrong_width_request_rejected_at_admission() {
        let (net, svc) = service(ServiceCfg::default());
        assert!(matches!(svc.submit(vec![1, 2, 3]), Err(SubmitError::Invalid(_))));
        assert!(matches!(svc.submit(vec![1, 2, 3, 0, 0]), Err(SubmitError::Invalid(_))));
        // submit_blocking must return the width error, not retry it
        assert!(svc.submit_blocking(vec![0; 9]).is_err());
        // a well-formed neighbor is unaffected
        let codes = vec![1u32, 2, 3, 0];
        let resp = svc.submit_blocking(codes.clone()).unwrap();
        assert_eq!(resp.sums, sim::eval(&net, &codes));
        assert_eq!(svc.stats().completed, 1);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // zero workers can't drain; queue_depth 4 must reject the 5th+
        let ck = synthetic(&[2, 2], &[3, 6], 7);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(
            net,
            ServiceCfg { workers: 0, queue_depth: 4, ..Default::default() },
        );
        let mut oks = 0;
        let mut errs = 0;
        let mut rxs = Vec::new();
        for _ in 0..10 {
            match svc.submit(vec![0, 1]) {
                Ok(rx) => {
                    oks += 1;
                    rxs.push(rx);
                }
                Err(e) => {
                    assert_eq!(e, SubmitError::Backpressure);
                    errs += 1;
                }
            }
        }
        assert_eq!(oks, 4);
        assert_eq!(errs, 6);
        assert_eq!(svc.stats().rejected, 6);
    }

    #[test]
    fn hot_swap_while_serving() {
        // paper §6: LUT updates during operation; in-flight batches keep
        // their snapshot, later requests see the new table
        let ck = synthetic(&[3, 2], &[3, 6], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(Arc::clone(&net), ServiceCfg::default());
        let codes = vec![1u32, 2, 3];
        let before = svc.submit_blocking(codes.clone()).unwrap().sums;
        assert_eq!(before, sim::eval(&net, &codes));
        // swap neuron 0's first active edge to a constant table
        let p = net.layers[0].neurons[0].luts[0].input;
        let n_codes = 1usize << ck.bits[0];
        svc.swap_edge(0, 0, p, vec![999_999; n_codes]).unwrap();
        let after = svc.submit_blocking(codes.clone()).unwrap().sums;
        assert_ne!(before[0], after[0]);
        // invalid swaps rejected while serving
        assert!(svc.swap_edge(7, 0, 0, vec![0; n_codes]).is_err());
        svc.shutdown();
    }

    #[test]
    fn batching_aggregates() {
        let (_, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            queue_depth: 1024,
            ..Default::default()
        });
        let rxs: Vec<_> = (0..64).map(|_| svc.submit(vec![1, 2, 3, 0]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = svc.stats();
        assert!(stats.mean_batch > 1.5, "mean batch {}", stats.mean_batch);
        svc.shutdown();
    }

    #[test]
    fn submit_blocking_errors_after_shutdown() {
        // regression: the old catch-all retry loop treated "service
        // stopped" as backpressure and spun forever
        let (_, svc) = service(ServiceCfg::default());
        svc.submit_blocking(vec![1, 2, 3, 0]).unwrap();
        svc.shutdown();
        assert_eq!(svc.submit(vec![1, 2, 3, 0]).unwrap_err(), SubmitError::Stopped);
        let t = Instant::now();
        assert!(svc.submit_blocking(vec![1, 2, 3, 0]).is_err());
        assert!(
            t.elapsed() < Duration::from_secs(1),
            "submit_blocking kept retrying after shutdown ({:?})",
            t.elapsed()
        );
        // shutdown is idempotent
        svc.shutdown();
    }

    #[test]
    fn batches_form_while_others_execute() {
        // pipelining witness: with both executors asleep inside a batch,
        // the dispatcher must keep forming batches (under the old
        // lock-convoy design, formation was serialized with execution and
        // nothing could form until a worker finished)
        let (_, svc) = service(ServiceCfg {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            queue_depth: 1024,
            exec_delay: Duration::from_millis(500),
            ..Default::default()
        });
        // 16 requests = 4 full batches; 2 execute (sleeping), 2 must form behind them
        let rxs: Vec<_> = (0..16).map(|_| svc.submit(vec![1, 2, 3, 0]).unwrap()).collect();
        std::thread::sleep(Duration::from_millis(200));
        let st = svc.stats();
        assert_eq!(st.completed, 0, "executors are still sleeping");
        assert!(
            st.batches >= 3,
            "dispatcher should pipeline formation past the 2 executing batches, formed {}",
            st.batches
        );
        for rx in rxs {
            rx.recv().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn lone_request_flushes_after_max_wait_from_submission() {
        let (_, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(40),
            ..Default::default()
        });
        let t = Instant::now();
        let resp = svc.submit_blocking(vec![1, 2, 3, 0]).unwrap();
        // dispatched by the max_wait flush (not earlier), measured from
        // submission (not from some later collection start)
        assert!(resp.latency >= Duration::from_millis(30), "flushed early: {:?}", resp.latency);
        assert!(t.elapsed() < Duration::from_secs(2), "waited far past max_wait");
        svc.shutdown();
    }

    #[test]
    fn latency_tracking_is_bounded() {
        // more requests than the reservoir retains: quantiles stay sane
        let (_, svc) = service(ServiceCfg {
            workers: 2,
            max_batch: 64,
            max_wait: Duration::from_micros(10),
            queue_depth: 1 << 14,
            ..Default::default()
        });
        let mut pending = Vec::new();
        for _ in 0..2 * LATENCY_RESERVOIR {
            loop {
                match svc.submit(vec![1, 2, 3, 0]) {
                    Ok(rx) => {
                        pending.push(rx);
                        break;
                    }
                    Err(SubmitError::Backpressure) => {
                        for rx in pending.drain(..) {
                            rx.recv().unwrap();
                        }
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let st = svc.stats();
        assert_eq!(st.completed, 2 * LATENCY_RESERVOIR as u64);
        assert!(st.latency_p50_us.is_finite() && st.latency_p50_us > 0.0);
        assert!(st.latency_p99_us >= st.latency_p50_us);
        svc.shutdown();
    }
}
