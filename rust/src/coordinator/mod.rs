//! L3 coordinator: a multi-tenant, sharded dispatcher/executor serving
//! plane over a registry of netlists.
//!
//! The paper's deployment story is a streaming accelerator core (II = 1)
//! fed by a host; this module is that host-side system. PR 2 split batch
//! *formation* from batch *execution*, PR 4 sharded the plane; this
//! revision makes it **multi-tenant**: one coordinator serves N
//! independently loaded checkpoints ([`ModelRegistry`]) with per-tenant
//! fairness, quotas, statistics, and live canarying.
//!
//! ```text
//!        ModelRegistry   "default"(id 0) | "ft-a"(id 1) | ... | (id N-1)
//!                        each tenant: NetlistCell -> ProgramCell @ its own
//!                        OptLevel, in-flight quota, counters, optional
//!                        Canary (x% of rows -> 2nd checkpoint, live argmax
//!                        agreement); reintern() shares identical tables
//!                        across tenants in ONE arena
//!                           │ resolved ONCE at admission -> Arc<Tenant>
//!                           ▼   travels with the request
//!            shard 0: [admission q0] -> DRR dispatcher 0 -> [deque 0] -\
//! clients ==>shard 1: [admission q1] -> DRR dispatcher 1 -> [deque 1] --+=> executors
//!   submit_model:        ...          deficit-round-robin      ...     /   pop home deque,
//!   client-affine     bounded,        over per-tenant       bounded,       steal when idle,
//!   round-robin,      backpressure    queues; batches are   per-shard      run each BATCH's
//!   spill when full   + tenant quota  single-tenant                        tenant snapshot
//! ```
//!
//! **Admission** is S bounded channels. [`Service::submit_model`] resolves
//! the [`ModelId`] to its `Arc<`[`registry::Tenant`]`>` once, enforces the
//! tenant's in-flight quota, then picks a shard by client-affine
//! round-robin (each submitting thread gets a sticky seed, so one client's
//! requests stay FIFO on one shard) and spills to the next shard only
//! under local backpressure, so total capacity stays work-conserving.
//! **Formation** is one dispatcher thread per shard, each the sole owner
//! of its receiver, forming batches with [`batcher::DrrCollector`]:
//! requests are split into per-tenant queues and served deficit-round-
//! robin, so a heavy tenant's backlog cannot starve a light tenant's
//! latency, and every batch is **single-tenant** (executors run one
//! snapshot per batch). Dispatch conditions are the same as
//! [`batcher::Policy::decide`] — `max_batch` fill or `max_wait` aged from
//! each request's *submission* — and with one tenant the collector is
//! proven batch-for-batch identical to the PR-6 [`batcher::collect_with`]
//! pipeline. **Execution** is a work-stealing pool ([`steal::WorkPool`]):
//! dispatchers push formed [`batcher::Batch`]es onto their shard's bounded
//! deque, executors pop their home deque and steal the *oldest* batch from
//! a victim when idle. Each batch carries its tenant handle, so executors
//! never touch the registry: they load the tenant's `(netlist, program)`
//! snapshot, run the batch (plus the canaried row subset on the canary
//! program), and complete per-tenant and service-wide counters.
//!
//! Executors run on a [`Backend`]: the default is the compiled flat
//! program of [`crate::engine`] (batch-major, hot-swap aware via
//! [`ProgramCell`], cross-checked against [`crate::sim`] in debug builds);
//! the netlist-walking interpreter remains selectable for debugging and
//! A/B benchmarking.
//!
//! **Intra-batch data-parallelism** ([`ServiceCfg::parallel_grain`]): one
//! very large compiled batch would otherwise serialize on one executor
//! while the rest idle. Past the threshold (`>= 2 * parallel_grain` valid
//! rows, `workers > 1`) the executing thread splits the batch's sample
//! dimension into up to `workers` even contiguous ranges, offers all but
//! the first back onto the SAME work-stealing deques as slice tasks
//! (non-blocking: a full deque runs that range inline), runs its own
//! range, helps with *other* batches' slices while it waits, and stitches
//! the per-range output planes in sample order — byte-for-byte the
//! single-executor plane, because samples are independent and the engine's
//! chunked kernels never mix samples across a slice boundary. The grain
//! itself is adaptive by default (`parallel_grain == 0`): unsliced
//! compiled batches feed a bounded reservoir of observed per-row
//! nanoseconds, and each large batch derives its grain from the mean —
//! targeting ~0.5 ms of work per slice, clamped to `[256, 8192]` samples
//! — so fast models get coarse slices that amortize the fan-out and slow
//! models get fine ones that actually spread. Explicit grains remain
//! fixed overrides; [`GRAIN_OFF`] is the kill switch. Small batches never
//! see any of this: below the threshold the code path is exactly the
//! pre-slicing one. A panicked slice poisons its job's latch; the
//! originator then panics into its supervisor and the whole batch fails
//! with the same typed replies as any other contained panic.
//!
//! Statistics are kept per shard ([`ShardStats`]), per tenant
//! ([`TenantStats`]: admitted/completed/batches/latency quantiles/quota
//! drops/canary agreement, retained after unload) plus service-wide
//! counters; [`Service::stats`] aggregates them into one [`ServiceStats`]
//! snapshot whose totals are consistent with both breakdowns it carries
//! (writers bump tenant counters first, the snapshot reads totals first,
//! so `sum(per_tenant) >= total` holds even mid-traffic and exactly at
//! quiescence).
//!
//! **Failure containment**: executors and dispatchers are *supervised* —
//! each batch runs under `catch_unwind`, so a poisoned batch fails its own
//! requests with typed [`SubmitError::Failed`] replies instead of killing
//! the worker; the thread rebuilds its scratch state in place and keeps
//! serving (bounded restarts, `exec_panics`/`respawns` counters). A
//! per-tenant circuit breaker quarantines a tenant whose batches
//! repeatedly panic ([`registry::QUARANTINE_TRIP`] consecutive strikes
//! open it for [`registry::QUARANTINE_WINDOW`]; the first admission after
//! the window half-opens it, [`registry::Tenant::probe`] re-probes
//! manually), so one bad checkpoint cannot take down co-tenants. Requests
//! may carry a **deadline** ([`Service::submit_deadline`]): the DRR
//! batcher sheds already-expired requests at formation time with a typed
//! [`SubmitError::Expired`] reply, so an overloaded plane answers fresh
//! requests on time instead of everything late. A seeded [`FaultPlan`]
//! injects deterministic panics for the chaos bench; it is all-off by
//! default and every injection hook sits behind one disarmed check.
//!
//! Failure modes → typed outcome at the client → who retries → counter:
//!
//! | failure | outcome | retry? | counter |
//! |---|---|---|---|
//! | admission queues full | `Err(Backpressure)` at submit | yes, backoff | `rejected` |
//! | tenant quota full | `Err(Backpressure)` at submit | yes, backoff | `quota_drops` |
//! | tenant quarantined | `Err(Quarantined)` at submit | after window / probe | `quarantine_drops` |
//! | batch panicked | `Err(Failed)` reply | new request | `failed`, `exec_panics` |
//! | deadline expired | `Err(Expired)` reply | no — answer is stale | `shed_expired` |
//! | width raced a swap | reply channel closed | new request | `dropped` |
//! | service stopped | `Err(Stopped)` at submit | no | — |
//!
//! Shutdown is graceful across shards: [`Service::shutdown`] disconnects
//! every admission channel, each dispatcher drains and dispatches what was
//! already admitted and closes its producer handle on the pool, executors
//! drain the deques and exit, and any later `submit*` call fails fast with
//! [`SubmitError::Stopped`] instead of spinning.

pub mod batcher;
pub mod registry;
pub mod steal;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::engine::{CompiledProgram, Executor, InternStats, OptLevel, OptReport, ProgramCell};
use crate::netlist::hotswap::NetlistCell;
use crate::netlist::Netlist;
use crate::sim;
use crate::util::Reservoir;

use batcher::{Batch, DrrCollector, Policy, Timestamped};
use steal::WorkPool;

pub use registry::{ModelId, ModelRegistry, TenantStats};

/// Retained latency samples: quantiles stay approximately correct under
/// sustained load at O(1) memory (the previous unbounded summary retained
/// every sample of every request forever).
const LATENCY_RESERVOIR: usize = 4096;

/// One inference request (input codes).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Tenant this request routes to ([`ModelId::DEFAULT`] for
    /// single-tenant services) — also the batcher's fairness key.
    pub model: ModelId,
    pub codes: Vec<u32>,
    pub submitted: Instant,
    /// Absolute formation deadline (computed at admission from the
    /// caller's `deadline_us`). The batcher sheds the request with a
    /// typed [`SubmitError::Expired`] reply instead of executing it late.
    pub deadline: Option<Instant>,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub sums: Vec<i64>,
    /// Queue + batch + execute time.
    pub latency: Duration,
}

/// Every admitted request gets **exactly one** typed outcome on its reply
/// channel: `Ok(Response)`, or a terminal `Err` ([`SubmitError::Failed`]
/// for a panicked batch, [`SubmitError::Expired`] for a deadline shed).
/// A closed channel (`RecvError`) is the `dropped` case: admission raced
/// a whole-model replace, or shutdown discarded a parked request.
pub type Reply = Result<Response, SubmitError>;

struct Pending {
    req: Request,
    /// Resolved once at admission; executors run the batch on this handle
    /// without any registry lookup, and an unloaded tenant's snapshot
    /// stays alive exactly until its in-flight work drains.
    tenant: Arc<registry::Tenant>,
    /// RAII quota slot: decrements the tenant's in-flight gauge on every
    /// exit path (completion, width drop, shutdown discard).
    _inflight: registry::InflightGuard,
    reply: SyncSender<Reply>,
}

impl Timestamped for Pending {
    fn submitted(&self) -> Instant {
        self.req.submitted
    }

    fn deadline(&self) -> Option<Instant> {
        self.req.deadline
    }
}

impl batcher::Keyed for Pending {
    fn key(&self) -> u32 {
        self.req.model.raw()
    }
}

/// Why admission failed. Callers must distinguish retryable backpressure
/// from terminal conditions — retrying a stopped service or a malformed
/// request spins forever.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queues full (every shard tried); retrying later can succeed.
    Backpressure,
    /// Service shut down; no retry will ever succeed.
    Stopped,
    /// Malformed request (wrong input width); no retry will ever succeed.
    Invalid(String),
    /// No tenant with that id is loaded (never was, or was unloaded);
    /// terminal for this request.
    UnknownModel(String),
    /// The batch carrying this request panicked (poisoned input, bad
    /// swap, injected fault) — the supervisor contained it and failed the
    /// whole batch; a fresh submit may succeed. Also returned at
    /// admission when a dispatcher crashed outright (its supervisor
    /// exhausted its restarts) while the service is still up, which is
    /// distinguishable from a clean [`SubmitError::Stopped`].
    Failed,
    /// The request's deadline expired before its batch formed; it was
    /// shed unexecuted. Retrying is pointless — the answer is stale by
    /// definition.
    Expired,
    /// The named tenant's circuit breaker is open (its batches repeatedly
    /// panicked); admissions are refused until the quarantine window
    /// elapses (half-open) or [`registry::Tenant::probe`] re-probes.
    /// Co-tenants are unaffected.
    Quarantined(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "admission queues full (backpressure)"),
            SubmitError::Stopped => write!(f, "service stopped"),
            SubmitError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            SubmitError::UnknownModel(m) => write!(f, "unknown model: {m}"),
            SubmitError::Failed => write!(f, "request failed (batch panicked)"),
            SubmitError::Expired => write!(f, "request deadline expired before execution"),
            SubmitError::Quarantined(m) => write!(f, "model '{m}' quarantined (repeated panics)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Which executor the worker pool runs batches on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Flat compiled program ([`crate::engine`]): batch-major table scans.
    /// The serving default.
    #[default]
    Compiled,
    /// Netlist-graph interpreter ([`crate::sim::Evaluator`]): per-sample
    /// walk. Kept for debugging and as the A/B baseline.
    Interpreted,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "compiled" | "engine" => Some(Backend::Compiled),
            "interpreted" | "sim" => Some(Backend::Interpreted),
            _ => None,
        }
    }
}

/// Seeded, deterministic fault-injection plan ([`ServiceCfg::faults`];
/// all-off by default). Given the same plan and the same executed-batch
/// sequence, the same slots fire — the chaos bench and tests assert exact
/// counter totals, not "some faults happened". Production configs leave
/// it disarmed; the executor fast path is one `armed()` check.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Phase offset into the executed-batch sequence: slot `k` fires when
    /// `(k + seed) % panic_every == 0`.
    pub seed: u64,
    /// Panic every Nth executed batch (of `panic_model`'s batches when
    /// set); `0` disarms panic injection entirely.
    pub panic_every: usize,
    /// Total injected panics before the plan goes quiet (`0` means
    /// unlimited). Lets quarantine tests watch a tenant trip, half-open
    /// and then recover on clean traffic.
    pub panic_budget: usize,
    /// Restrict panic injection to one tenant's batches so co-tenant
    /// isolation is observable; `None` poisons any tenant.
    pub panic_model: Option<ModelId>,
}

impl FaultPlan {
    /// Whether any fault is armed (when not, injection costs one branch).
    pub fn armed(&self) -> bool {
        self.panic_every > 0
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceCfg {
    /// Executor threads; batch formation uses one extra dispatcher thread
    /// *per shard* (none of either is spawned when `workers == 0`).
    pub workers: usize,
    /// Admission shards, each with its own bounded queue and dispatcher.
    /// Clamped to `[1, workers]` at start (with stealing off, every shard
    /// needs at least one home executor or its batches would strand).
    pub shards: usize,
    /// Idle executors steal the oldest queued batch from other shards'
    /// deques. With one shard this is moot (all executors share the one
    /// deque); with several it is what keeps heavy-tailed batch costs from
    /// convoying behind a single shard.
    pub steal: bool,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bounded admission capacity, **total across shards** (each shard's
    /// queue gets `queue_depth / shards`, at least 1).
    pub queue_depth: usize,
    pub backend: Backend,
    /// Pass-pipeline level the compiled backend lowers programs at
    /// (including recompiles after hot-swaps). [`OptLevel::Full`] — fold
    /// pruned-constant edges, eliminate dead inputs, hash-cons/CSE tables —
    /// is the production default; [`OptLevel::None`] keeps the 1:1 lowering
    /// for A/B runs. Ignored by the interpreted backend.
    pub opt: OptLevel,
    /// Artificial per-batch execution delay. Zero in production; test and
    /// bench instrumentation that stretches execution so pipeline overlap
    /// and steal rebalancing are observable on microsecond workloads.
    pub exec_delay: Duration,
    /// Restrict `exec_delay` to batches formed by one shard (deterministic
    /// heavy-tail: one slow shard, the rest fast). `None` delays all.
    pub exec_delay_shard: Option<usize>,
    /// Apply `exec_delay` to every Nth executed batch only (service-wide
    /// execution sequence); `0`/`1` delay every batch. Synthetic
    /// heavy-tailed load for benches.
    pub exec_delay_every: usize,
    /// Deterministic fault injection (all-off by default): drives the
    /// chaos bench and the CI smoke; production configs never arm it.
    pub faults: FaultPlan,
    /// Intra-batch data-parallelism grain, in samples. A compiled batch
    /// with at least `2 * grain` valid rows is split into up to `workers`
    /// grain-sized sample ranges; the ranges fan out across the executor
    /// pool as slice tasks and the originating executor stitches the
    /// per-range planes back together (sample order preserved, so the
    /// output is byte-for-byte what the unsliced path produces). Batches
    /// below the threshold — and everything when `workers <= 1` — take
    /// the single-executor path untouched: slicing only ever engages
    /// where the fan-out overhead is amortized over thousands of samples.
    ///
    /// `0` (the default) means **auto**: each large batch derives its
    /// grain from the per-row nanoseconds observed on earlier unsliced
    /// compiled batches, targeting [`AUTO_GRAIN_TARGET_NS`] of work per
    /// slice and clamped to `[`[`AUTO_GRAIN_MIN`]`, `[`AUTO_GRAIN_MAX`]`]`
    /// samples ([`AUTO_GRAIN_COLD`] until the first timing sample lands).
    /// Any other value is a fixed override; [`GRAIN_OFF`] disables
    /// slicing entirely.
    pub parallel_grain: usize,
}

/// Sentinel for [`ServiceCfg::parallel_grain`]: disables intra-batch
/// slicing entirely — the kill switch `0` used to be before `0` came to
/// mean auto. (No real batch has `2 * GRAIN_OFF` rows, saturating.)
pub const GRAIN_OFF: usize = usize::MAX;

/// Auto-grain slice target: each fanned-out sample range should carry
/// about this much execution time, so the fan-out overhead (task push,
/// latch, stitch) stays well under a percent of the work it spreads.
pub const AUTO_GRAIN_TARGET_NS: f64 = 500_000.0;

/// Auto-grain floor, samples: finer than this and per-slice overhead
/// dominates even for very slow models.
pub const AUTO_GRAIN_MIN: usize = 256;

/// Auto-grain ceiling, samples: coarser than this and a fast model's
/// large batch no longer spreads across a small pool.
pub const AUTO_GRAIN_MAX: usize = 8192;

/// Auto grain used while the timing reservoir is empty — the old fixed
/// default, so a cold service behaves exactly like the pre-auto one.
pub const AUTO_GRAIN_COLD: usize = 2048;

/// Per-row timing samples retained for auto-grain derivation.
const GRAIN_RESERVOIR: usize = 512;

/// Derive the intra-batch slice grain from observed per-row execution
/// time (see [`ServiceCfg::parallel_grain`]): target
/// [`AUTO_GRAIN_TARGET_NS`] per slice, clamp to
/// `[AUTO_GRAIN_MIN, AUTO_GRAIN_MAX]`, fall back to [`AUTO_GRAIN_COLD`]
/// with no (or degenerate) samples. Pure — unit-tested directly.
fn auto_grain(per_row_ns: f64) -> usize {
    if !per_row_ns.is_finite() || per_row_ns <= 0.0 {
        return AUTO_GRAIN_COLD;
    }
    ((AUTO_GRAIN_TARGET_NS / per_row_ns) as usize).clamp(AUTO_GRAIN_MIN, AUTO_GRAIN_MAX)
}

impl Default for ServiceCfg {
    fn default() -> Self {
        ServiceCfg {
            workers: 4,
            shards: 1,
            steal: true,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            backend: Backend::Compiled,
            opt: OptLevel::default(),
            exec_delay: Duration::ZERO,
            exec_delay_shard: None,
            exec_delay_every: 0,
            faults: FaultPlan::default(),
            parallel_grain: 0,
        }
    }
}

/// One admission shard's statistics. The flush counters partition
/// `batches` (`flush_full + flush_timeout + flush_disconnect == batches`)
/// in a quiescent snapshot; a snapshot taken while the shard's dispatcher
/// is mid-publish can be transiently off by the in-flight batch (the five
/// counters are separate relaxed stores, not one atomic struct).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Requests admitted into this shard's queue.
    pub admitted: u64,
    /// Batches formed by this shard's dispatcher.
    pub batches: u64,
    pub mean_batch: f64,
    /// Batches dispatched because they filled to `max_batch`.
    pub flush_full: u64,
    /// Batches flushed because the oldest request aged out `max_wait`.
    pub flush_timeout: u64,
    /// Partial batches flushed by shutdown disconnecting admission.
    pub flush_disconnect: u64,
    /// Requests this shard's dispatcher shed at formation because their
    /// deadline had already expired (typed `Expired` reply).
    pub shed_expired: u64,
}

/// Aggregated service statistics. Totals (`batches`, `mean_batch`, ...)
/// are the aggregation of the `per_shard` breakdown carried alongside, so
/// one snapshot is internally consistent.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub completed: u64,
    pub rejected: u64,
    /// Admitted but never executed: the request's width stopped matching
    /// the model snapshot (admission raced a `replace_model`). The client
    /// observes a closed reply channel.
    pub dropped: u64,
    /// Admissions refused by per-tenant in-flight quotas (summed over
    /// tenants; disjoint from `rejected`, which is queue backpressure).
    pub quota_drops: u64,
    /// Requests answered with a typed [`SubmitError::Failed`] reply
    /// because their batch panicked (real bug or injected fault).
    /// Disjoint from `dropped`: failed requests got an explicit answer.
    pub failed: u64,
    /// Requests shed at batch formation because their deadline had
    /// already expired; each got a typed [`SubmitError::Expired`] reply.
    pub shed_expired: u64,
    /// Batch executions that panicked and were contained by the
    /// supervisor (each failed its own requests; the worker survived).
    pub exec_panics: u64,
    /// In-thread supervisor restarts: executor scratch rebuilt after a
    /// caught panic, or a dispatcher loop re-entered. Bounded per thread.
    pub respawns: u64,
    /// Admissions refused because the tenant's circuit breaker was open
    /// (repeated batch panics); summed over tenants like `quota_drops`.
    pub quarantine_drops: u64,
    /// Faults injected by the seeded [`FaultPlan`] (`0` in production).
    pub faults_injected: u64,
    /// Batches formed by the dispatchers (counted at formation, so under
    /// load this runs ahead of execution — the pipeline is visible here).
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p90_us: f64,
    pub latency_p99_us: f64,
    /// Samples per second over the service lifetime.
    pub throughput_rps: f64,
    /// Fused LUT ops executed (samples x ops-per-sample). Counts work
    /// actually run: the interpreter walks every netlist L-LUT, while the
    /// compiled backend runs the *optimized* op stream (`opt.ops_after`) —
    /// so a pruned model legitimately reports fewer ops per sample on the
    /// compiled backend than interpreted or than pre-optimizer PRs.
    pub fused_ops: u64,
    /// Fused LUT ops per second over the service lifetime. Comparable
    /// across batch sizes and worker counts at a fixed backend + opt
    /// level; across backends/levels compare `throughput_rps` (the
    /// optimizer removes ops, it does not slow them down).
    pub throughput_ops: f64,
    /// Largest executor scratch footprint observed (bytes).
    pub scratch_bytes: u64,
    /// Batches the compiled backend split into intra-batch sample slices
    /// (at least `2 * parallel_grain` valid rows; see
    /// [`ServiceCfg::parallel_grain`]). `0` proves every batch took the
    /// single-executor path.
    pub sliced_batches: u64,
    /// Slice tasks actually fanned out to the executor pool (excludes the
    /// originator's own range and any range it ran inline because the
    /// deques were full).
    pub slice_tasks: u64,
    /// What the compiled backend's pass pipeline did to the *current*
    /// program snapshot (ops/table/lane before-after). `None` for the
    /// interpreted backend or a worker-less service.
    pub opt: Option<OptReport>,
    /// Batches executors popped from their own shard's deque.
    pub local_pops: u64,
    /// Batches idle executors stole from another shard's deque.
    pub steals: u64,
    /// Per-admission-shard breakdown; `len() == cfg.shards`.
    pub per_shard: Vec<ShardStats>,
    /// Per-tenant breakdown: live tenants sorted by id, then unloaded
    /// (retired) tenants' frozen history. In a quiescent snapshot the sums
    /// of admitted/completed/dropped/quota_drops/batches over this list
    /// equal the service totals (mid-traffic, sums run `>=` the totals —
    /// see [`registry::TenantCounters`]).
    pub per_tenant: Vec<TenantStats>,
    /// Cross-tenant arena interning result from the last
    /// [`ModelRegistry::reintern`] pass (`None` when never interned or
    /// invalidated by a registry change since).
    pub arena: Option<InternStats>,
}

/// Per-shard shared counters. `admitted` is written by submitters
/// (fetch_add); everything else is single-writer — the shard's dispatcher
/// publishes its `CollectStats` running totals with plain stores.
#[derive(Default)]
struct ShardShared {
    admitted: AtomicU64,
    batches: AtomicU64,
    batched: AtomicU64,
    flush_full: AtomicU64,
    flush_timeout: AtomicU64,
    flush_disconnect: AtomicU64,
    shed_expired: AtomicU64,
}

impl ShardShared {
    /// Publish the dispatcher's running totals (sole writer: stores).
    fn publish(&self, cs: &batcher::CollectStats) {
        self.batches.store(cs.batches, Ordering::Relaxed);
        self.batched.store(cs.items, Ordering::Relaxed);
        self.flush_full.store(cs.flush_full, Ordering::Relaxed);
        self.flush_timeout.store(cs.flush_timeout, Ordering::Relaxed);
        self.flush_disconnect.store(cs.flush_disconnect, Ordering::Relaxed);
        self.shed_expired.store(cs.shed_expired, Ordering::Relaxed);
    }
}

struct Shared {
    /// Bounded reservoir — O(1) memory no matter how long the service runs.
    latencies: Mutex<Reservoir>,
    completed: AtomicU64,
    rejected: AtomicU64,
    dropped: AtomicU64,
    quota_drops: AtomicU64,
    /// Fused LUT ops executed (valid samples x ops-per-sample), counted at
    /// execution. Per-sample ops are the backend's own: netlist L-LUTs for
    /// the interpreter, the optimized op stream for the compiled engine
    /// (see [`ServiceStats::fused_ops`]).
    fused_ops: AtomicU64,
    /// Largest executor scratch footprint observed, bytes (feature-major
    /// planes grow to the biggest batch seen and never shrink).
    scratch: AtomicU64,
    /// Service-wide executed-batch sequence (only advanced when
    /// `exec_delay_every` instrumentation is armed).
    exec_seq: AtomicU64,
    /// Requests failed with a typed reply by a panicked batch.
    failed: AtomicU64,
    /// Requests shed at formation past their deadline (service total;
    /// the per-shard split rides in each dispatcher's `CollectStats`).
    shed_expired: AtomicU64,
    /// Batch executions the supervisor caught panicking.
    exec_panics: AtomicU64,
    /// In-thread supervisor restarts (executor or dispatcher).
    respawns: AtomicU64,
    /// Admissions refused by open tenant circuit breakers.
    quarantine_drops: AtomicU64,
    /// Injected-fault slot sequence for [`FaultPlan`] (targeted batches
    /// only, so "every Nth" is relative to the targeted tenant).
    fault_seq: AtomicU64,
    /// Panics actually injected; doubles as the budget gauge.
    faults_injected: AtomicU64,
    /// Compiled batches split into intra-batch sample slices.
    sliced_batches: AtomicU64,
    /// Slice tasks fanned out to the pool (originator ranges excluded).
    slice_tasks: AtomicU64,
    /// Per-row execution nanoseconds observed on unsliced compiled
    /// batches; the auto grain derives from its mean (see
    /// [`ServiceCfg::parallel_grain`]). Only fed in auto mode.
    row_ns: Mutex<Reservoir>,
    shards: Vec<ShardShared>,
}

/// What travels on the executor deques. Dispatchers only ever push whole
/// formed batches; slice tasks are pushed by an executor that decided to
/// split one large compiled batch across the pool (see
/// [`ServiceCfg::parallel_grain`]). Keeping both on the SAME deques means
/// slices inherit the pool's stealing, shutdown and accounting for free —
/// idle executors pick slices up exactly like batches, and the originator
/// drains *slice* work (never nested batches) while it waits for its own.
enum Work {
    Batch(Batch<Pending>),
    Slice(SliceTask),
}

/// One sliced compiled batch: the shared state every slice task of that
/// batch hangs off. `rows` are indices into `batch.items` whose width
/// matched the program snapshot (the same filter the unsliced path
/// applies), so slice ranges address *valid* samples only and the stitched
/// plane is byte-identical to one `run_batch_into` over all of them.
struct SliceJob {
    batch: Arc<Batch<Pending>>,
    /// The originator's program snapshot: every slice runs the SAME
    /// program even if a hot-swap lands mid-batch (PR-region semantics).
    prog: Arc<CompiledProgram>,
    /// Valid-row indices into `batch.items`, in batch order.
    rows: Vec<usize>,
    /// One output plane per slice, filled by whoever ran it; the
    /// originator stitches them in index order (== sample order).
    slots: Mutex<Vec<Option<Vec<i64>>>>,
    /// Counts the slices the originator did NOT run as its own range
    /// (fanned out or inline-fallback); poisoned if any of them panicked.
    latch: SliceLatch,
}

/// One contiguous valid-row range `[lo, hi)` of a [`SliceJob`].
struct SliceTask {
    job: Arc<SliceJob>,
    /// Slot index this task's output plane lands in.
    idx: usize,
    lo: usize,
    hi: usize,
}

/// Completion latch for a sliced batch: the originator parks on it while
/// helpers finish. `complete(false)` poisons it — the originator then
/// panics into its supervisor so the whole batch fails with typed replies
/// (slices have no reply channels of their own; the batch does). Waits
/// are short-timeout polls, mirroring the pool's defensive-poll shape, so
/// a lost wakeup costs a poll interval and never a hang.
struct SliceLatch {
    /// `(slices outstanding, any slice panicked)`.
    state: Mutex<(usize, bool)>,
    cond: Condvar,
}

impl SliceLatch {
    fn new(remaining: usize) -> SliceLatch {
        SliceLatch { state: Mutex::new((remaining, false)), cond: Condvar::new() }
    }

    fn complete(&self, ok: bool) {
        let mut s = self.state.lock().unwrap();
        s.0 -= 1;
        if !ok {
            s.1 = true;
        }
        if s.0 == 0 {
            self.cond.notify_all();
        }
    }

    fn done(&self) -> bool {
        self.state.lock().unwrap().0 == 0
    }

    fn poisoned(&self) -> bool {
        self.state.lock().unwrap().1
    }

    /// Park until every outstanding slice completes or `timeout` passes
    /// (callers re-check `done` in a loop; the timeout is the safety poll).
    fn wait(&self, timeout: Duration) {
        let s = self.state.lock().unwrap();
        if s.0 > 0 {
            let _ = self.cond.wait_timeout(s, timeout).unwrap();
        }
    }
}

/// An executor's reusable per-thread scratch: the engine executor plus the
/// two flat output planes (primary + canary rows). Bundled so the
/// supervisor can rebuild all of it in one assignment after a caught
/// panic, and so `execute_batch` takes one scratch handle instead of three
/// `&mut` parameters.
struct ExecScratch {
    exec: Executor,
    /// Caller-owned output plane of `run_batch_into` for the whole batch.
    flat: Vec<i64>,
    /// The canaried row subset's plane for the same batch.
    flat2: Vec<i64>,
}

/// The executor pool as seen from inside one executor: the shared deques
/// plus this thread's home shard (where it offers slice tasks and looks
/// first when draining slice work).
struct PoolRef<'a> {
    pool: &'a WorkPool<Work>,
    home: usize,
}

/// Condvar wakeup for `submit_blocking`'s backpressure waits: dispatchers
/// bump the generation whenever they drain requests out of an admission
/// queue, so blocked submitters park instead of sleep-spinning. A sibling
/// of the eventcount gate inside [`steal::WorkPool`] (same
/// generation+condvar+defensive-poll shape, different condition), kept
/// separate because the conditions and ownership differ. `bump` is on the
/// dispatcher's per-batch path, so it skips the lock entirely while no
/// submitter is parked; the one race that allows (a waiter registering
/// concurrently with the skipped bump) costs at most one poll interval —
/// submitters re-check admission on every wake either way.
struct DrainGate {
    gen: Mutex<u64>,
    cond: Condvar,
    /// Submitters parked (or about to re-check); bumps skip the lock at 0.
    waiters: AtomicUsize,
}

impl DrainGate {
    const POLL: Duration = Duration::from_millis(1);

    fn new() -> DrainGate {
        DrainGate { gen: Mutex::new(0), cond: Condvar::new(), waiters: AtomicUsize::new(0) }
    }

    fn generation(&self) -> u64 {
        *self.gen.lock().unwrap()
    }

    fn bump(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return; // nobody parked: keep the dispatch path lock-free
        }
        *self.gen.lock().unwrap() += 1;
        self.cond.notify_all();
    }

    /// Block until the generation moves past `seen` (or the safety poll
    /// expires). Callers read `seen` *before* their failed admission
    /// attempt, so a drain that lands in between either already moved the
    /// generation or at worst costs one poll interval.
    fn wait_past(&self, seen: u64) {
        let mut g = self.gen.lock().unwrap();
        self.waiters.fetch_add(1, Ordering::SeqCst);
        while *g == seen {
            let (g2, timeout) = self.cond.wait_timeout(g, Self::POLL).unwrap();
            g = g2;
            if timeout.timed_out() {
                break;
            }
        }
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Sticky client-affine shard seed: each submitting thread takes the next
/// value of a process-wide round-robin counter on first use, so one
/// client's requests keep landing on one shard (per-client FIFO order, warm
/// dispatcher) while distinct clients spread across shards.
fn affine_seed() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SEED: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SEED.with(|c| {
        if c.get() == usize::MAX {
            c.set(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        c.get()
    })
}

/// Batched inference service over a netlist.
pub struct Service {
    /// Per-shard admission senders; taken (→ `None`) by
    /// [`Service::shutdown`], which disconnects every dispatcher at once.
    /// RwLock so concurrent submitters share a read lock on the hot path.
    txs: RwLock<Option<Vec<SyncSender<Pending>>>>,
    /// With zero workers there are no dispatchers to own the admission
    /// receivers; parked here so the queues stay connected and backpressure
    /// is observable without anything draining them.
    rx_parked: Mutex<Vec<Receiver<Pending>>>,
    /// Dispatcher → executor handoff; `None` when `workers == 0`.
    pool: Option<Arc<WorkPool<Work>>>,
    drain: Arc<DrainGate>,
    /// Tenant registry: every loaded checkpoint with its own swappable
    /// cell, compiled-program cache, quota, counters and optional canary.
    /// Single-tenant starts wrap their cell in a one-entry registry
    /// (tenant `"default"`, [`ModelId::DEFAULT`]).
    registry: Arc<ModelRegistry>,
    shared: Arc<Shared>,
    next_id: AtomicU64,
    started: Instant,
    /// Dispatchers + executors; drained on shutdown.
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    cfg: ServiceCfg,
}

impl Service {
    pub fn start(net: Arc<Netlist>, cfg: ServiceCfg) -> Service {
        Self::start_swappable(Arc::new(NetlistCell::new(net)), cfg)
    }

    /// Start over a swappable cell: edge tables (or the whole model) can be
    /// replaced while serving; in-flight batches finish on their snapshot.
    /// The cell becomes the single tenant `"default"` of a fresh registry,
    /// compiled at `cfg.opt` — the exact pre-registry plane.
    pub fn start_swappable(cell: Arc<NetlistCell>, cfg: ServiceCfg) -> Service {
        Self::start_registry(Arc::new(ModelRegistry::single(cell, cfg.opt)), cfg)
    }

    /// Start over a multi-tenant registry. The first-loaded tenant
    /// ([`ModelId::DEFAULT`]) is the default route for model-less submits
    /// and wire frames. Tenants compile at the *registry's* level;
    /// `cfg.opt` only governs registries built by
    /// [`Service::start_swappable`].
    pub fn start_registry(registry: Arc<ModelRegistry>, cfg: ServiceCfg) -> Service {
        let mut cfg = cfg;
        cfg.shards = cfg.shards.max(1);
        if cfg.workers > 0 {
            // with stealing off every shard needs a home executor; with it
            // on, more dispatchers than executors is pure overhead
            cfg.shards = cfg.shards.min(cfg.workers);
        }
        let per_shard_depth = (cfg.queue_depth / cfg.shards).max(1);
        let mut txs = Vec::with_capacity(cfg.shards);
        let mut rxs = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let (tx, rx) = sync_channel::<Pending>(per_shard_depth);
            txs.push(tx);
            rxs.push(rx);
        }
        let shared = Arc::new(Shared {
            latencies: Mutex::new(Reservoir::new(LATENCY_RESERVOIR)),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            quota_drops: AtomicU64::new(0),
            fused_ops: AtomicU64::new(0),
            scratch: AtomicU64::new(0),
            exec_seq: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            shed_expired: AtomicU64::new(0),
            exec_panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            quarantine_drops: AtomicU64::new(0),
            fault_seq: AtomicU64::new(0),
            faults_injected: AtomicU64::new(0),
            sliced_batches: AtomicU64::new(0),
            slice_tasks: AtomicU64::new(0),
            row_ns: Mutex::new(Reservoir::new(GRAIN_RESERVOIR)),
            shards: (0..cfg.shards).map(|_| ShardShared::default()).collect(),
        });
        let drain = Arc::new(DrainGate::new());
        let mut threads = Vec::with_capacity(cfg.workers + cfg.shards);
        let mut rx_parked = Vec::new();
        let mut pool = None;
        if cfg.workers == 0 {
            rx_parked = rxs;
        } else {
            // executors carry no fixed backend handle — every batch brings
            // its own tenant snapshot. The default tenant's program (when
            // compiled) only warm-sizes each executor's scratch planes.
            let warm = match cfg.backend {
                Backend::Compiled => {
                    registry.resolve(ModelId::DEFAULT).map(|t| Arc::clone(t.programs()))
                }
                Backend::Interpreted => None,
            };
            // per-shard deque depth ~ executors per shard (rounded up, so
            // the total staged budget is never below the old single work
            // channel of depth `workers`): every executor can be running
            // one batch with another staged before a dispatcher blocks.
            // With intra-batch slicing armed, each shard gets `workers`
            // extra slots of headroom so slice offers (non-blocking
            // `try_push`) land even while batches are staged — a full
            // deque only costs the originator an inline slice, never a
            // block.
            let slice_headroom = if cfg.parallel_grain != GRAIN_OFF { cfg.workers } else { 0 };
            let deque_cap = cfg.workers.div_ceil(cfg.shards) + slice_headroom;
            let p: Arc<WorkPool<Work>> =
                Arc::new(WorkPool::new(cfg.shards, deque_cap, cfg.steal, cfg.shards, cfg.workers));
            for w in 0..cfg.workers {
                let pool = Arc::clone(&p);
                let home = w % cfg.shards;
                let warm = warm.clone();
                let shared = Arc::clone(&shared);
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("kanele-exec-{w}"))
                        .spawn(move || executor_loop(pool, home, warm, shared, cfg))
                        .expect("spawn executor"),
                );
            }
            let policy =
                Policy { max_batch: cfg.max_batch, max_wait: cfg.max_wait, ..Default::default() };
            for (s, rx) in rxs.into_iter().enumerate() {
                let pool = Arc::clone(&p);
                let shared = Arc::clone(&shared);
                let drain = Arc::clone(&drain);
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("kanele-dispatch-{s}"))
                        .spawn(move || dispatcher_loop(s, rx, pool, policy, shared, drain))
                        .expect("spawn dispatcher"),
                );
            }
            pool = Some(p);
        }
        Service {
            txs: RwLock::new(Some(txs)),
            rx_parked: Mutex::new(rx_parked),
            pool,
            drain,
            registry,
            shared,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            threads: Mutex::new(threads),
            cfg,
        }
    }

    /// The tenant registry — load/unload/swap checkpoints, canary setup,
    /// cross-tenant interning, per-tenant resolution for wire front ends.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    fn default_tenant(&self) -> Result<Arc<registry::Tenant>> {
        self.registry
            .resolve(ModelId::DEFAULT)
            .ok_or_else(|| anyhow!("no default tenant loaded"))
    }

    /// Hot-swap one edge table of the default tenant while serving (paper
    /// §6 future work). Other tenants: go through [`Service::registry`].
    pub fn swap_edge(&self, layer: usize, q: usize, p: usize, table: Vec<i64>) -> Result<()> {
        self.default_tenant()?.cell().swap_edge(layer, q, p, table)
    }

    /// Replace the default tenant's whole model while serving.
    pub fn replace_model(&self, net: Arc<Netlist>) {
        if let Ok(t) = self.default_tenant() {
            t.cell().replace(net);
        }
    }

    /// Admission core: resolve the tenant, validate width against ITS
    /// snapshot, claim a quota slot, then try the start shard and
    /// (unpinned) spill through the remaining shards before declaring
    /// backpressure. On failure the request's codes are handed back where
    /// recoverable, so retry loops never clone the payload.
    fn submit_shard(
        &self,
        pin: Option<usize>,
        model: ModelId,
        codes: Vec<u32>,
        deadline_us: Option<u64>,
    ) -> Result<Receiver<Reply>, (SubmitError, Option<Vec<u32>>)> {
        // resolved + validated on every call: a concurrent unload or
        // swap can change the tenant set and widths between retries
        let Some(tenant) = self.registry.resolve(model) else {
            return Err((SubmitError::UnknownModel(format!("id {model}")), Some(codes)));
        };
        // a wrong-width row inside a compiled batch would shift every
        // later sample in the batch-major input plane: reject here
        let want = tenant.input_width();
        if codes.len() != want {
            return Err((
                SubmitError::Invalid(format!(
                    "request width {} != model '{}' input width {want}",
                    codes.len(),
                    tenant.name()
                )),
                Some(codes),
            ));
        }
        // circuit breaker before quota: a quarantined tenant is refused
        // while its window runs. The first admission after the window
        // half-opens the breaker (one probe batch: clean closes it,
        // another panic re-trips). Tenant counter first (inside
        // `breaker_admit`), then service-wide — the consistency ordering.
        if !tenant.breaker_admit() {
            self.shared.quarantine_drops.fetch_add(1, Ordering::Relaxed);
            return Err((SubmitError::Quarantined(tenant.name().to_string()), Some(codes)));
        }
        // quota before queueing: a tenant at its in-flight cap is refused
        // without consuming shared admission capacity (tenant counter
        // first, then service-wide — the stats consistency ordering)
        let Some(quota_slot) = tenant.try_admit() else {
            tenant.counters().quota_drops.fetch_add(1, Ordering::Relaxed);
            self.shared.quota_drops.fetch_add(1, Ordering::Relaxed);
            return Err((SubmitError::Backpressure, Some(codes)));
        };
        let guard = self.txs.read().unwrap();
        let Some(txs) = guard.as_ref() else {
            return Err((SubmitError::Stopped, Some(codes)));
        };
        let n = txs.len();
        let (start, tries) = match pin {
            Some(s) => (s % n, 1),
            None => (affine_seed() % n, n),
        };
        let (reply_tx, reply_rx) = sync_channel(1);
        let now = Instant::now();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model,
            codes,
            submitted: now,
            deadline: deadline_us.map(|us| now + Duration::from_micros(us)),
        };
        let mut pending =
            Pending { req, tenant: Arc::clone(&tenant), _inflight: quota_slot, reply: reply_tx };
        for i in 0..tries {
            let s = (start + i) % n;
            match txs[s].try_send(pending) {
                Ok(()) => {
                    tenant.counters().admitted.fetch_add(1, Ordering::Relaxed);
                    self.shared.shards[s].admitted.fetch_add(1, Ordering::Relaxed);
                    return Ok(reply_rx);
                }
                Err(TrySendError::Full(p)) => pending = p,
                // the dispatcher died while the service is still up (we
                // hold the txs read lock, so shutdown cannot have begun):
                // that is a crash — its supervisor exhausted its restarts
                // — not a clean stop, and clients must be able to tell
                Err(TrySendError::Disconnected(p)) => {
                    return Err((SubmitError::Failed, Some(p.req.codes)))
                }
            }
        }
        tenant.counters().rejected.fetch_add(1, Ordering::Relaxed);
        self.shared.rejected.fetch_add(1, Ordering::Relaxed);
        Err((SubmitError::Backpressure, Some(pending.req.codes)))
    }

    /// Submit a request to the default tenant; the returned receiver
    /// yields the typed outcome ([`Reply`]). Fails fast with a typed
    /// [`SubmitError`]: wrong width, unknown model, quarantine and
    /// shutdown are terminal, full admission queues (and full tenant
    /// quotas) are retryable backpressure.
    pub fn submit(&self, codes: Vec<u32>) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_model(ModelId::DEFAULT, codes)
    }

    /// [`Service::submit`] with a relative deadline: a request still
    /// unformed `deadline_us` after this call is shed with a typed
    /// [`SubmitError::Expired`] reply instead of executing late.
    /// `None` never expires.
    pub fn submit_deadline(
        &self,
        codes: Vec<u32>,
        deadline_us: Option<u64>,
    ) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_shard(None, ModelId::DEFAULT, codes, deadline_us).map_err(|(e, _)| e)
    }

    /// [`Service::submit`] routed to an explicit tenant.
    pub fn submit_model(
        &self,
        model: ModelId,
        codes: Vec<u32>,
    ) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_shard(None, model, codes, None).map_err(|(e, _)| e)
    }

    /// [`Service::submit_deadline`] routed to an explicit tenant.
    pub fn submit_model_deadline(
        &self,
        model: ModelId,
        codes: Vec<u32>,
        deadline_us: Option<u64>,
    ) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_shard(None, model, codes, deadline_us).map_err(|(e, _)| e)
    }

    /// [`Service::submit`] that hands the codes back on recoverable
    /// failures, so closed-loop clients retry without re-cloning the
    /// payload.
    pub fn try_submit(
        &self,
        codes: Vec<u32>,
    ) -> Result<Receiver<Reply>, (SubmitError, Option<Vec<u32>>)> {
        self.submit_shard(None, ModelId::DEFAULT, codes, None)
    }

    /// [`Service::try_submit`] routed to an explicit tenant.
    pub fn try_submit_model(
        &self,
        model: ModelId,
        codes: Vec<u32>,
    ) -> Result<Receiver<Reply>, (SubmitError, Option<Vec<u32>>)> {
        self.submit_shard(None, model, codes, None)
    }

    /// Submit pinned to one admission shard — no affine spill. For tests,
    /// benches and clients doing their own placement; `shard` is taken
    /// modulo the shard count.
    pub fn submit_to(&self, shard: usize, codes: Vec<u32>) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_shard(Some(shard), ModelId::DEFAULT, codes, None).map_err(|(e, _)| e)
    }

    /// [`Service::submit_to`] routed to an explicit tenant.
    pub fn submit_to_model(
        &self,
        shard: usize,
        model: ModelId,
        codes: Vec<u32>,
    ) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_shard(Some(shard), model, codes, None).map_err(|(e, _)| e)
    }

    /// [`Service::submit_to_model`] with a relative deadline
    /// (see [`Service::submit_deadline`]).
    pub fn submit_to_model_deadline(
        &self,
        shard: usize,
        model: ModelId,
        codes: Vec<u32>,
        deadline_us: Option<u64>,
    ) -> Result<Receiver<Reply>, SubmitError> {
        self.submit_shard(Some(shard), model, codes, deadline_us).map_err(|(e, _)| e)
    }

    /// Submit with blocking retry (used by the closed-loop example). Only
    /// backpressure retries — parked on the drain gate until a dispatcher
    /// frees admission slots, not sleep-spinning — and the request codes
    /// are moved through each attempt, never cloned. Malformed requests,
    /// unknown models and a stopped service return the error immediately.
    pub fn submit_blocking(&self, codes: Vec<u32>) -> Result<Response> {
        self.submit_blocking_model(ModelId::DEFAULT, codes)
    }

    /// [`Service::submit_blocking`] routed to an explicit tenant.
    pub fn submit_blocking_model(&self, model: ModelId, codes: Vec<u32>) -> Result<Response> {
        let mut codes = codes;
        loop {
            // read the generation BEFORE attempting: a drain landing
            // between the failed try and the wait shows as a moved
            // generation, so the wait returns immediately (no lost wakeup)
            let seen = self.drain.generation();
            match self.try_submit_model(model, codes) {
                Ok(rx) => {
                    return rx
                        .recv()
                        .context("request dropped (model swap or shutdown mid-flight)")?
                        .map_err(Into::into)
                }
                Err((SubmitError::Backpressure, reclaimed)) => {
                    codes = reclaimed.expect("backpressure hands the codes back");
                    self.drain.wait_past(seen);
                }
                Err((e, _)) => return Err(e.into()),
            }
        }
    }

    pub fn stats(&self) -> ServiceStats {
        // read order is the other half of the consistency contract:
        // service-wide totals FIRST, per-tenant counters last (writers
        // bump tenant-first), so sum(per_tenant) >= total always holds in
        // one snapshot and equality holds at quiescence
        let [p50, p90, p99] = self.shared.latencies.lock().unwrap().p50_p90_p99();
        let completed = self.shared.completed.load(Ordering::Relaxed);
        let rejected = self.shared.rejected.load(Ordering::Relaxed);
        let dropped = self.shared.dropped.load(Ordering::Relaxed);
        let quota_drops = self.shared.quota_drops.load(Ordering::Relaxed);
        let failed = self.shared.failed.load(Ordering::Relaxed);
        let shed_expired = self.shared.shed_expired.load(Ordering::Relaxed);
        let exec_panics = self.shared.exec_panics.load(Ordering::Relaxed);
        let respawns = self.shared.respawns.load(Ordering::Relaxed);
        let quarantine_drops = self.shared.quarantine_drops.load(Ordering::Relaxed);
        let faults_injected = self.shared.faults_injected.load(Ordering::Relaxed);
        let fused_ops = self.shared.fused_ops.load(Ordering::Relaxed);
        let mut per_shard = Vec::with_capacity(self.shared.shards.len());
        let (mut batches, mut batched) = (0u64, 0u64);
        for ss in &self.shared.shards {
            let b = ss.batches.load(Ordering::Relaxed);
            let n = ss.batched.load(Ordering::Relaxed);
            per_shard.push(ShardStats {
                admitted: ss.admitted.load(Ordering::Relaxed),
                batches: b,
                mean_batch: if b == 0 { 0.0 } else { n as f64 / b as f64 },
                flush_full: ss.flush_full.load(Ordering::Relaxed),
                flush_timeout: ss.flush_timeout.load(Ordering::Relaxed),
                flush_disconnect: ss.flush_disconnect.load(Ordering::Relaxed),
                shed_expired: ss.shed_expired.load(Ordering::Relaxed),
            });
            batches += b;
            batched += n;
        }
        let (local_pops, steals) = match &self.pool {
            Some(p) => {
                let ps = p.stats();
                (ps.local, ps.stolen)
            }
            None => (0, 0),
        };
        let per_tenant = self.registry.tenant_stats();
        #[cfg(debug_assertions)]
        {
            let sum = |f: fn(&TenantStats) -> u64| per_tenant.iter().map(f).sum::<u64>();
            debug_assert!(sum(|t| t.completed) >= completed, "per-tenant completed undercounts");
            debug_assert!(sum(|t| t.dropped) >= dropped, "per-tenant dropped undercounts");
            debug_assert!(sum(|t| t.rejected) >= rejected, "per-tenant rejected undercounts");
            debug_assert!(
                sum(|t| t.quota_drops) >= quota_drops,
                "per-tenant quota_drops undercounts"
            );
            debug_assert!(sum(|t| t.failed) >= failed, "per-tenant failed undercounts");
            debug_assert!(
                sum(|t| t.shed_expired) >= shed_expired,
                "per-tenant shed_expired undercounts"
            );
            debug_assert!(
                sum(|t| t.quarantine_drops) >= quarantine_drops,
                "per-tenant quarantine_drops undercounts"
            );
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        ServiceStats {
            completed,
            rejected,
            dropped,
            quota_drops,
            failed,
            shed_expired,
            exec_panics,
            respawns,
            quarantine_drops,
            faults_injected,
            batches,
            mean_batch: if batches == 0 { 0.0 } else { batched as f64 / batches as f64 },
            latency_p50_us: p50 * 1e6,
            latency_p90_us: p90 * 1e6,
            latency_p99_us: p99 * 1e6,
            throughput_rps: completed as f64 / elapsed,
            fused_ops,
            throughput_ops: fused_ops as f64 / elapsed,
            scratch_bytes: self.shared.scratch.load(Ordering::Relaxed),
            sliced_batches: self.shared.sliced_batches.load(Ordering::Relaxed),
            slice_tasks: self.shared.slice_tasks.load(Ordering::Relaxed),
            // the default tenant's CURRENT snapshot report (a hot-swap
            // recompile updates it); loading here may pay the first
            // post-swap recompile, which stats consumers can afford.
            // None for the interpreted backend or a worker-less service,
            // matching the pre-registry surface.
            opt: if self.cfg.workers > 0 && self.cfg.backend == Backend::Compiled {
                self.registry
                    .resolve(ModelId::DEFAULT)
                    .and_then(|t| t.programs().load().1.opt_report().cloned())
            } else {
                None
            },
            local_pops,
            steals,
            per_shard,
            per_tenant,
            arena: self.registry.arena_stats(),
        }
    }

    /// Effective configuration (shards clamped, see [`ServiceCfg::shards`]).
    pub fn cfg(&self) -> ServiceCfg {
        self.cfg
    }

    /// Whether [`Service::shutdown`] has begun (admission disconnected).
    /// Front ends (e.g. [`crate::net`]) poll this to stop accepting new
    /// wire work while the plane drains — any `submit*` after this returns
    /// `true` fails fast with [`SubmitError::Stopped`].
    pub fn is_stopped(&self) -> bool {
        self.txs.read().unwrap().is_none()
    }

    /// Input width of the default tenant's current snapshot (`0` when no
    /// default tenant is loaded). Wire front ends advertise this in
    /// `stats` frames so remote clients can size requests without holding
    /// the checkpoint; per-tenant widths come from the registry.
    pub fn input_width(&self) -> usize {
        self.registry.resolve(ModelId::DEFAULT).map(|t| t.input_width()).unwrap_or(0)
    }

    /// Stop the plane and join its threads. Graceful: everything already
    /// admitted on any shard is dispatched and executed first. Idempotent,
    /// and callable through a shared reference (e.g. an `Arc<Service>`
    /// while other clients still hold clones — their next `submit*` fails
    /// fast with [`SubmitError::Stopped`]).
    pub fn shutdown(&self) {
        // disconnect all admission shards at once: each dispatcher drains
        // its queue, forwards the final partial batch, and closes its
        // producer handle on the work pool; once the last closes, executors
        // drain the deques and wind down
        self.txs.write().unwrap().take();
        self.rx_parked.lock().unwrap().clear();
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Consecutive caught panics a supervised thread tolerates before giving
/// up. One poisoned batch resets the streak on the next clean one; only a
/// panic *storm* this dense — a plane bug, not bad input — stops a thread.
const SUPERVISOR_MAX_RESTARTS: usize = 16;

/// Pipeline stage 1, one per shard — sole owner of its admission receiver.
/// Requests are split into per-tenant queues and dispatched deficit-round-
/// robin by [`batcher::DrrCollector`] (dispatch conditions identical to
/// [`batcher::Policy::decide`]; single-tenant traffic degenerates to the
/// [`batcher::collect_with`] pipeline batch-for-batch); formed batches are
/// single-tenant and go onto this shard's deque in the work-stealing pool.
/// Requests whose deadline lapsed before formation are shed with a typed
/// [`SubmitError::Expired`] reply the moment the collector notices them.
/// Supervised: the collector and running stats live OUTSIDE the unwind
/// boundary, so a caught panic (defensive — formation runs no tenant
/// code) loses nothing already queued; the loop re-enters and keeps
/// forming, bounded by [`SUPERVISOR_MAX_RESTARTS`]. Exits when admission
/// is disconnected and drained, closing its producer handle so the pool
/// can wind down.
fn dispatcher_loop(
    shard: usize,
    rx: Receiver<Pending>,
    pool: Arc<WorkPool<Work>>,
    policy: Policy,
    shared: Arc<Shared>,
    drain: Arc<DrainGate>,
) {
    let mut cs = batcher::CollectStats::default();
    let mut drr = DrrCollector::new(policy);
    let mut restarts = 0usize;
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            // typed outcome for a stale request: tenant counter first,
            // then service-wide (the consistency ordering); the per-shard
            // split rides in `cs` and publishes with the batch totals
            let mut shed = |p: Pending| {
                let tc = p.tenant.counters();
                tc.shed_expired.fetch_add(1, Ordering::Relaxed);
                shared.shed_expired.fetch_add(1, Ordering::Relaxed);
                let _ = p.reply.try_send(Err(SubmitError::Expired));
            };
            while let Some(batch) = drr.next_with(&rx, &mut cs, &mut shed) {
                // per-tenant formation accounting, tenant counter first
                // (the DRR collector never mixes tenants within a batch)
                let tc = batch.items[0].tenant.counters();
                tc.batches.fetch_add(1, Ordering::Relaxed);
                tc.batch_items.fetch_add(batch.len() as u64, Ordering::Relaxed);
                shared.shards[shard].publish(&cs);
                // admission slots just freed: wake submitters parked on
                // backpressure (before push, which may block on a full deque)
                drain.bump();
                if !pool.push(shard, Work::Batch(batch)) {
                    break; // every executor died; nothing left to feed
                }
            }
        }));
        match run {
            Ok(()) => break, // admission drained + disconnected: graceful
            Err(_) => {
                shared.respawns.fetch_add(1, Ordering::Relaxed);
                restarts += 1;
                if restarts >= SUPERVISOR_MAX_RESTARTS {
                    // submitters now see the disconnected sender as the
                    // typed `Failed` (crash), not a clean `Stopped`
                    break;
                }
            }
        }
    }
    // trailing sheds with no batch formed after them still publish
    shared.shards[shard].publish(&cs);
    pool.close_producer();
}

/// Pipeline stage 2 — pop work (home shard first, stealing the oldest
/// from victims when idle) and run it. Only executors with nothing local
/// to do ever touch another shard's deque, so executions overlap freely
/// and no lock is held across a batch-collection wait. Work is either a
/// whole formed batch or one slice of a large batch another executor
/// split (see [`ServiceCfg::parallel_grain`]); slices run on this
/// thread's scratch exactly like batches do. Supervised: each batch runs
/// under `catch_unwind` with the batch owned OUT HERE, so a panicked
/// execution still answers every request (typed [`SubmitError::Failed`]),
/// takes a breaker strike on its tenant, and the executor rebuilds its
/// scratch state and keeps consuming; a panicked *slice* poisons its
/// job's latch instead (the originator fails the whole batch with the
/// same typed replies). The OS thread never dies for a contained panic,
/// so the pool's fixed producer/consumer accounting is untouched.
fn executor_loop(
    pool: Arc<WorkPool<Work>>,
    home: usize,
    warm: Option<Arc<ProgramCell>>,
    shared: Arc<Shared>,
    cfg: ServiceCfg,
) {
    // RAII consumer registration: runs on normal wind-down AND on the
    // (now only restart-exhausted) exit, so once the last executor is
    // gone dispatchers fail their push instead of blocking forever on a
    // deque nothing will drain
    struct ConsumerGuard<'a>(&'a WorkPool<Work>);
    impl Drop for ConsumerGuard<'_> {
        fn drop(&mut self) {
            self.0.close_consumer();
        }
    }
    let _consumer = ConsumerGuard(&pool);
    // per-executor scratch, reused across batches, TENANTS and hot-swaps
    // (the Executor grows to the largest geometry it serves), warm-sized
    // from the default tenant so steady state never allocates planes.
    let fresh = |warm: &Option<Arc<ProgramCell>>| ExecScratch {
        exec: match warm {
            Some(programs) => Executor::with_capacity(&programs.load().1, cfg.max_batch),
            None => Executor::new(),
        },
        flat: Vec::new(),
        flat2: Vec::new(),
    };
    let mut scratch = fresh(&warm);
    let mut consecutive = 0usize;
    while let Some((src_shard, work)) = pool.pop(home) {
        let ok = match work {
            Work::Batch(batch) => {
                // Arc-owned OUT HERE: a slice job shares the batch with
                // helper executors, and a panic below still leaves every
                // reply sender alive for `fail_batch` to answer
                let batch = Arc::new(batch);
                let run = catch_unwind(AssertUnwindSafe(|| {
                    let pool = PoolRef { pool: &pool, home };
                    execute_batch(&batch, src_shard, &mut scratch, pool, &shared, &cfg);
                }));
                if run.is_err() {
                    fail_batch(&batch, &shared);
                }
                run.is_ok()
            }
            // helper side of a sliced batch: catches its own panics and
            // completes/poisons the job latch either way
            Work::Slice(task) => run_slice(task, &mut scratch.exec),
        };
        if ok {
            consecutive = 0;
        } else {
            // scratch may be torn mid-write: rebuild before reuse
            scratch = fresh(&warm);
            shared.respawns.fetch_add(1, Ordering::Relaxed);
            consecutive += 1;
            if consecutive >= SUPERVISOR_MAX_RESTARTS {
                // a panic storm this dense is a plane bug, not one bad
                // batch: stop consuming (the guard closes the slot so
                // dispatchers fail fast instead of blocking)
                break;
            }
        }
    }
    // pool drained and every dispatcher closed: graceful exit
}

/// Run one slice of another executor's batch on this thread's scratch.
/// Panics are contained HERE (the slice has no reply channels to answer —
/// the originating batch does): the job latch is completed either way,
/// poisoned on panic, and the originator fails the whole batch through
/// its own supervisor. Returns whether the slice ran clean so the caller
/// can rebuild possibly-torn scratch.
fn run_slice(task: SliceTask, exec: &mut Executor) -> bool {
    let ok = catch_unwind(AssertUnwindSafe(|| run_slice_body(&task, exec))).is_ok();
    task.job.latch.complete(ok);
    ok
}

/// The slice itself: gather the range's rows off the shared batch, run
/// them through the job's program snapshot, park the output plane in the
/// task's slot. Row indices pre-filtered at job construction, so every
/// row here matches the program width. Batch-level panic accounting
/// (`exec_panics` / `failed` / breaker strike) lands once, in the
/// originator's `fail_batch`, when the poisoned latch fails the whole
/// batch — only the respawn is the helper's own.
fn run_slice_body(task: &SliceTask, exec: &mut Executor) {
    let job = &task.job;
    let rows: Vec<&[u32]> = job.rows[task.lo..task.hi]
        .iter()
        .map(|&r| job.batch.items[r].req.codes.as_slice())
        .collect();
    let mut out = Vec::with_capacity((task.hi - task.lo) * job.prog.d_out());
    exec.run_batch_into(&job.prog, &rows, &mut out);
    job.slots.lock().unwrap()[task.idx] = Some(out);
}

/// Complete a poisoned batch with typed outcomes: every request gets an
/// explicit [`SubmitError::Failed`] reply (tenant counters first, then
/// service-wide — the stats consistency ordering) and the tenant's
/// circuit breaker takes a strike, so repeat offenders are quarantined.
/// `try_send` (capacity-1 reply channels are empty unless a response was
/// already delivered) keeps this path non-blocking no matter where the
/// unwind started.
fn fail_batch(batch: &Batch<Pending>, shared: &Shared) {
    let tenant = &batch.items[0].tenant;
    let n = batch.items.len() as u64;
    let tc = tenant.counters();
    tc.panics.fetch_add(1, Ordering::Relaxed);
    tc.failed.fetch_add(n, Ordering::Relaxed);
    shared.exec_panics.fetch_add(1, Ordering::Relaxed);
    shared.failed.fetch_add(n, Ordering::Relaxed);
    tenant.breaker_panic();
    for p in &batch.items {
        let _ = p.reply.try_send(Err(SubmitError::Failed));
    }
}

/// Deterministic injection decision for one about-to-run batch. The slot
/// counter only advances for batches the plan targets, so "every Nth
/// batch" is relative to the targeted tenant; budget slots are claimed
/// with a CAS so concurrent executors never overshoot the budget.
fn should_inject_panic(shared: &Shared, plan: &FaultPlan, model: ModelId) -> bool {
    if plan.panic_every == 0 {
        return false;
    }
    if plan.panic_model.is_some_and(|m| m != model) {
        return false;
    }
    let slot = shared.fault_seq.fetch_add(1, Ordering::Relaxed);
    if slot.wrapping_add(plan.seed) % plan.panic_every as u64 != 0 {
        return false;
    }
    if plan.panic_budget > 0 {
        let mut used = shared.faults_injected.load(Ordering::Relaxed);
        loop {
            if used >= plan.panic_budget as u64 {
                return false;
            }
            match shared.faults_injected.compare_exchange(
                used,
                used + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(u) => used = u,
            }
        }
    }
    shared.faults_injected.fetch_add(1, Ordering::Relaxed);
    true
}

/// Index of the largest sum, ties to the lowest index — the class an
/// argmax head predicts; canary agreement compares these per row.
fn argmax(sums: &[i64]) -> usize {
    let mut best = 0;
    for (i, v) in sums.iter().enumerate().skip(1) {
        if *v > sums[best] {
            best = i;
        }
    }
    best
}

/// Run one (single-tenant) batch on its tenant's snapshot and complete
/// its requests. `src_shard` is the admission shard whose dispatcher
/// formed the batch (it may differ from the executor's home shard —
/// that's a steal). When the tenant has a canary, the canaried row subset
/// ALSO runs on the canary program: those rows answer from the canary,
/// and their argmax is scored against the primary (which ran for every
/// row) into the tenant's live agreement counters. A compiled batch past
/// the [`ServiceCfg::parallel_grain`] threshold fans sample slices across
/// `pool` and stitches the identical output plane (see `execute_sliced`).
fn execute_batch(
    batch: &Arc<Batch<Pending>>,
    src_shard: usize,
    scratch: &mut ExecScratch,
    pool: PoolRef<'_>,
    shared: &Shared,
    cfg: &ServiceCfg,
) {
    let ExecScratch { exec, flat, flat2 } = scratch;
    // borrowed, not consumed: the batch stays owned (via its Arc) by the
    // supervising executor_loop, so a panic below leaves every reply
    // sender alive for `fail_batch` to answer (SyncSender::send takes
    // &self)
    let items = &batch.items;
    // the batch carries its tenant: executors never touch the registry,
    // and an unloaded tenant's snapshot lives until this drains
    let tenant = Arc::clone(&items[0].tenant);
    debug_assert!(
        items.iter().all(|p| p.req.model == items[0].req.model),
        "DRR batches are single-tenant"
    );
    // seeded fault injection: a claimed slot poisons this batch before
    // any row runs; the supervisor catches the unwind and fails it
    if cfg.faults.armed() && should_inject_panic(shared, &cfg.faults, items[0].req.model) {
        panic!("injected fault: FaultPlan poisons this batch");
    }
    let canary = tenant.canary_snapshot();
    let (mut canary_rows, mut canary_agree) = (0u64, 0u64);
    // batch-consistent snapshot: a concurrent hot-swap applies to the
    // NEXT batch, never mid-batch (PR-region semantics). Requests whose
    // width no longer matches the snapshot (admission raced a
    // whole-model replace) yield None: their reply channel is dropped
    // instead of corrupting co-batched samples.
    let outputs: Vec<Option<Vec<i64>>> = match cfg.backend {
        Backend::Compiled => {
            let (net, prog) = tenant.programs().load();
            let d_in = prog.d_in();
            let d_out = prog.d_out();
            let rows: Vec<&[u32]> = items
                .iter()
                .map(|p| p.req.codes.as_slice())
                .filter(|r| r.len() == d_in)
                .collect();
            // whole batch into the reused flat plane: the engine allocates
            // nothing; per-request sums are sliced out at completion. A
            // batch past the slicing threshold instead fans grain-sized
            // sample ranges across the pool and stitches the SAME plane
            // (byte-for-byte: samples are independent and keep their
            // batch order), so everything downstream — canary split,
            // debug sim cross-check, reply slicing — is path-agnostic.
            // Grain 0 resolves adaptively from observed per-row time;
            // GRAIN_OFF saturates the threshold so nothing ever slices.
            let grain = match cfg.parallel_grain {
                0 => auto_grain(shared.row_ns.lock().unwrap().mean()),
                g => g,
            };
            if cfg.workers > 1 && rows.len() >= grain.saturating_mul(2) {
                let row_idx: Vec<usize> = items
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.req.codes.len() == d_in)
                    .map(|(i, _)| i)
                    .collect();
                let k = (rows.len() / grain).min(cfg.workers).max(2);
                let job = Arc::new(SliceJob {
                    batch: Arc::clone(batch),
                    prog: Arc::clone(&prog),
                    rows: row_idx,
                    slots: Mutex::new(vec![None; k]),
                    latch: SliceLatch::new(k - 1),
                });
                shared.sliced_batches.fetch_add(1, Ordering::Relaxed);
                execute_sliced(&job, exec, flat, &pool, shared);
            } else {
                // unsliced compiled runs are the auto grain's sensor: one
                // per-row sample per batch (sliced runs are excluded —
                // their wall time is divided across helpers)
                let t0 = Instant::now();
                exec.run_batch_into(&prog, &rows, flat);
                if cfg.parallel_grain == 0 && !rows.is_empty() {
                    let ns = t0.elapsed().as_nanos() as f64 / rows.len() as f64;
                    shared.row_ns.lock().unwrap().push(ns);
                }
            }
            shared
                .fused_ops
                .fetch_add((rows.len() * prog.n_ops()) as u64, Ordering::Relaxed);
            // canary split: claim one global sequence slot per valid row
            // (exact percentages regardless of batching), run the chosen
            // subset on the canary program into flat2, score agreement
            let mask: Vec<bool> = match &canary {
                Some(c) => rows.iter().map(|_| c.take_row()).collect(),
                None => Vec::new(),
            };
            if let Some(c) = canary.as_ref().filter(|_| mask.contains(&true)) {
                let crows: Vec<&[u32]> =
                    rows.iter().zip(&mask).filter_map(|(r, &m)| m.then_some(*r)).collect();
                let (cnet, cprog) = c.programs().load();
                exec.run_batch_into(&cprog, &crows, flat2);
                shared
                    .fused_ops
                    .fetch_add((crows.len() * cprog.n_ops()) as u64, Ordering::Relaxed);
                let mut ci = 0usize;
                for (i, &m) in mask.iter().enumerate() {
                    if !m {
                        continue;
                    }
                    let prim = &flat[i * d_out..(i + 1) * d_out];
                    let can = &flat2[ci * d_out..(ci + 1) * d_out];
                    if argmax(prim) == argmax(can) {
                        canary_agree += 1;
                    }
                    ci += 1;
                }
                canary_rows = crows.len() as u64;
                if cfg!(debug_assertions) {
                    // tolerance is the canary's own compiled-in lossy
                    // bound: 0 for exact levels, so this degenerates to
                    // the old equality check everywhere but Lossy(b > 0)
                    let cbound = cprog
                        .opt_report()
                        .and_then(|r| r.lossy.as_ref())
                        .map_or(0, |l| l.worst_case_bound);
                    let mut ev = sim::Evaluator::new(&cnet);
                    for (k, row) in crows.iter().enumerate() {
                        let want = ev.eval(row);
                        let got = &flat2[k * d_out..(k + 1) * d_out];
                        debug_assert!(
                            got.iter().zip(want).all(|(g, w)| (g - w).abs() <= cbound),
                            "canary engine/sim divergence past lossy bound {cbound}"
                        );
                    }
                }
            }
            shared.scratch.fetch_max(exec.scratch_bytes() as u64, Ordering::Relaxed);
            // checked invariant: the compiled program IS the netlist — up
            // to its compiled-in lossy worst-case bound (0 for exact
            // levels, so this is the old equality check everywhere but
            // Lossy(b > 0) tenants, where it enforces the bound instead)
            if cfg!(debug_assertions) {
                let bound = prog
                    .opt_report()
                    .and_then(|r| r.lossy.as_ref())
                    .map_or(0, |l| l.worst_case_bound);
                let mut ev = sim::Evaluator::new(&net);
                for (i, row) in rows.iter().enumerate() {
                    let want = ev.eval(row);
                    let got = &flat[i * d_out..(i + 1) * d_out];
                    debug_assert!(
                        got.iter().zip(want).all(|(g, w)| (g - w).abs() <= bound),
                        "engine/sim divergence past lossy bound {bound}"
                    );
                }
            }
            // slice responses back out: canaried rows answer from the
            // canary plane, everything else from the primary plane
            let mut next = 0usize;
            let mut crow = 0usize;
            let mut outs = Vec::with_capacity(items.len());
            for p in items.iter() {
                outs.push((p.req.codes.len() == d_in).then(|| {
                    let i = next;
                    next += 1;
                    if mask.get(i).copied().unwrap_or(false) {
                        let k = crow;
                        crow += 1;
                        flat2[k * d_out..(k + 1) * d_out].to_vec()
                    } else {
                        flat[i * d_out..(i + 1) * d_out].to_vec()
                    }
                }));
            }
            outs
        }
        Backend::Interpreted => {
            let net = tenant.cell().load();
            let d_in = net.input_width();
            let ops_per_sample = net.n_luts() as u64;
            let mut ev = sim::Evaluator::new(&net);
            let cpair = canary.as_ref().map(|c| (c.cell().load(), c));
            let mut cev =
                cpair.as_ref().map(|(n, _)| (sim::Evaluator::new(n), n.n_luts() as u64));
            let mut valid = 0u64;
            let mut outs = Vec::with_capacity(items.len());
            for p in items.iter() {
                outs.push((p.req.codes.len() == d_in).then(|| {
                    valid += 1;
                    let prim = ev.eval(&p.req.codes).to_vec();
                    if let (Some((_, c)), Some((cev, cops))) = (&cpair, &mut cev) {
                        if c.take_row() {
                            let can = cev.eval(&p.req.codes).to_vec();
                            canary_rows += 1;
                            shared.fused_ops.fetch_add(*cops, Ordering::Relaxed);
                            if argmax(&can) == argmax(&prim) {
                                canary_agree += 1;
                            }
                            return can;
                        }
                    }
                    prim
                }));
            }
            shared.fused_ops.fetch_add(valid * ops_per_sample, Ordering::Relaxed);
            outs
        }
    };
    if canary_rows > 0 {
        let tc = tenant.counters();
        tc.canary_rows.fetch_add(canary_rows, Ordering::Relaxed);
        tc.canary_agree.fetch_add(canary_agree, Ordering::Relaxed);
    }
    if !cfg.exec_delay.is_zero() {
        let shard_hit = match cfg.exec_delay_shard {
            Some(s) => s == src_shard,
            None => true,
        };
        let every_hit = cfg.exec_delay_every <= 1
            || shared.exec_seq.fetch_add(1, Ordering::Relaxed) % cfg.exec_delay_every as u64 == 0;
        if shard_hit && every_hit {
            std::thread::sleep(cfg.exec_delay);
        }
    }
    let mut dropped = 0u64;
    let mut done: Vec<(&Pending, Vec<i64>, Duration)> = Vec::with_capacity(items.len());
    for (p, sums) in items.iter().zip(outputs) {
        match sums {
            Some(sums) => {
                let latency = p.req.submitted.elapsed();
                done.push((p, sums, latency));
            }
            // client sees RecvError on its reply channel
            None => dropped += 1,
        }
    }
    if dropped > 0 {
        // tenant counter first, service-wide second (stats consistency)
        tenant.counters().dropped.fetch_add(dropped, Ordering::Relaxed);
        shared.dropped.fetch_add(dropped, Ordering::Relaxed);
    }
    if !done.is_empty() {
        // one lock acquisition per reservoir for the whole batch, not one
        // per response; both store seconds
        {
            let mut lat = tenant.counters().latencies.lock().unwrap();
            for (_, _, latency) in &done {
                lat.push(latency.as_secs_f64());
            }
        }
        {
            let mut lat = shared.latencies.lock().unwrap();
            for (_, _, latency) in &done {
                lat.push(latency.as_secs_f64());
            }
        }
        // publish counts before replying so a client holding its response
        // always observes itself in `completed` (tenant first, again)
        tenant.counters().completed.fetch_add(done.len() as u64, Ordering::Relaxed);
        shared.completed.fetch_add(done.len() as u64, Ordering::Relaxed);
        for (p, sums, latency) in done {
            let _ = p.reply.send(Ok(Response { id: p.req.id, sums, latency }));
        }
    }
    // a clean batch resets the tenant's breaker strike streak, so only
    // CONSECUTIVE panics quarantine (one poisoned input among healthy
    // traffic fails its batch and nothing more)
    tenant.breaker_ok();
}

/// Originator side of a sliced batch: carve the valid rows into even
/// contiguous ranges, offer all but the first to the pool (non-blocking —
/// a full deque just means that range runs inline here), run the first
/// range, then join. While helpers finish, this thread drains OTHER
/// slice work off the deques (the predicate never admits a nested whole
/// batch, so recursion depth is one) and parks briefly when there is
/// none — two sliced batches in flight make progress on each other's
/// slices instead of deadlocking parked. A poisoned latch panics into
/// the originator's supervisor, failing the batch with typed replies.
/// Finally the per-range planes are stitched, in slot order == sample
/// order, into `flat` — byte-identical to one `run_batch_into` over all
/// valid rows.
fn execute_sliced(
    job: &Arc<SliceJob>,
    exec: &mut Executor,
    flat: &mut Vec<i64>,
    pool: &PoolRef<'_>,
    shared: &Shared,
) {
    let n = job.rows.len();
    let k = job.slots.lock().unwrap().len();
    let (base, rem) = (n / k, n % k);
    let mut ranges = Vec::with_capacity(k);
    let mut at = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        ranges.push((at, at + len));
        at += len;
    }
    for (i, &(lo, hi)) in ranges.iter().enumerate().skip(1) {
        let task = SliceTask { job: Arc::clone(job), idx: i, lo, hi };
        match pool.pool.try_push(pool.home, Work::Slice(task)) {
            Ok(()) => {
                shared.slice_tasks.fetch_add(1, Ordering::Relaxed);
            }
            Err(Work::Slice(task)) => {
                // deque full: run the range here rather than block the
                // fan-out (a panic unwinds into our own supervisor, and
                // outstanding helpers complete their slots harmlessly)
                run_slice_body(&task, exec);
                task.job.latch.complete(true);
            }
            Err(Work::Batch(_)) => unreachable!("pushed a slice"),
        }
    }
    let (lo, hi) = ranges[0];
    let own = SliceTask { job: Arc::clone(job), idx: 0, lo, hi };
    run_slice_body(&own, exec);
    while !job.latch.done() {
        let other = pool.pool.try_pop_where(pool.home, |w| matches!(w, Work::Slice(_)));
        match other {
            Some((_, Work::Slice(t))) => {
                // a foreign slice panicking must poison ITS latch before
                // unwinding into OUR supervisor: both batches then fail
                // with typed replies and neither originator spins on a
                // latch nobody will complete
                let r = catch_unwind(AssertUnwindSafe(|| run_slice_body(&t, exec)));
                t.job.latch.complete(r.is_ok());
                if let Err(p) = r {
                    std::panic::resume_unwind(p);
                }
            }
            Some((_, Work::Batch(_))) => unreachable!("predicate admits slices only"),
            None => job.latch.wait(Duration::from_millis(1)),
        }
    }
    if job.latch.poisoned() {
        panic!("slice execution panicked (job poisoned)");
    }
    flat.clear();
    let slots = job.slots.lock().unwrap();
    for s in slots.iter() {
        flat.extend_from_slice(s.as_deref().expect("completed slice filled its slot"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::{nearify, prunify, synthetic};
    use crate::lut;
    use crate::util::Rng;

    fn service(cfg: ServiceCfg) -> (Arc<Netlist>, Service) {
        let ck = synthetic(&[4, 3, 2], &[4, 5, 6], 2024);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(Arc::clone(&net), cfg);
        (net, svc)
    }

    #[test]
    fn both_backends_match_direct_eval() {
        for backend in [Backend::Compiled, Backend::Interpreted] {
            let (net, svc) = service(ServiceCfg { backend, ..Default::default() });
            let mut rng = Rng::new(42);
            let mut pending = Vec::new();
            let mut want = Vec::new();
            for _ in 0..100 {
                let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                want.push(sim::eval(&net, &codes));
                pending.push(svc.submit(codes).unwrap());
            }
            for (rx, w) in pending.into_iter().zip(want) {
                assert_eq!(rx.recv().unwrap().unwrap().sums, w, "{backend:?}");
            }
            // fused_ops counts work actually executed: the interpreter
            // walks every netlist L-LUT, the compiled backend runs the
            // optimized op stream (surfaced in stats.opt)
            let st = svc.stats();
            let ops_per_sample = match backend {
                Backend::Compiled => {
                    let opt = st.opt.as_ref().expect("compiled backend surfaces its report");
                    assert_eq!(opt.ops_before, net.n_luts());
                    assert!(opt.ops_after <= opt.ops_before);
                    opt.ops_after
                }
                Backend::Interpreted => {
                    assert!(st.opt.is_none(), "interpreter has no compiled program");
                    net.n_luts()
                }
            };
            assert_eq!(st.fused_ops, 100 * ops_per_sample as u64, "{backend:?}");
            svc.shutdown();
        }
    }

    #[test]
    fn responses_match_direct_eval() {
        let (net, svc) = service(ServiceCfg::default());
        let mut rng = Rng::new(1);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for _ in 0..200 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            want.push(sim::eval(&net, &codes));
            pending.push(svc.submit(codes).unwrap());
        }
        for (rx, w) in pending.into_iter().zip(want) {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.sums, w);
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 200);
        assert!(stats.batches >= 1);
        // ops accounting: every completed sample ran the whole (optimized)
        // program once
        let ops_per_sample = stats.opt.as_ref().expect("compiled default").ops_after;
        assert_eq!(stats.fused_ops, 200 * ops_per_sample as u64);
        assert!(stats.throughput_ops > 0.0);
        // the compiled backend publishes its feature-major scratch footprint
        assert!(stats.scratch_bytes > 0);
        svc.shutdown();
    }

    #[test]
    fn large_batches_slice_across_executors_and_stay_bit_exact() {
        // tiny grain + a wide batching window so one large batch forms:
        // the originator must fan sample slices across the pool and the
        // stitched responses must still match the sim oracle exactly
        let (net, svc) = service(ServiceCfg {
            workers: 4,
            shards: 1,
            max_batch: 512,
            max_wait: Duration::from_millis(100),
            queue_depth: 1 << 12,
            parallel_grain: 8,
            ..Default::default()
        });
        let mut rng = Rng::new(77);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for _ in 0..300 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            want.push(sim::eval(&net, &codes));
            pending.push(svc.submit(codes).unwrap());
        }
        for (rx, w) in pending.into_iter().zip(want) {
            assert_eq!(rx.recv().unwrap().unwrap().sums, w);
        }
        let st = svc.stats();
        assert_eq!(st.completed, 300);
        assert!(st.sliced_batches >= 1, "a batch past 2*grain valid rows must slice");
        assert!(st.slice_tasks >= 1, "slices must fan out to the pool");
        svc.shutdown();
    }

    #[test]
    fn small_batches_keep_the_single_executor_path() {
        // default auto grain, cold (falls back to 2048): nothing here
        // comes near the threshold, so the sliced counters must prove the
        // old path ran untouched
        let (net, svc) = service(ServiceCfg { workers: 4, ..Default::default() });
        let mut rng = Rng::new(78);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for _ in 0..100 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            want.push(sim::eval(&net, &codes));
            pending.push(svc.submit(codes).unwrap());
        }
        for (rx, w) in pending.into_iter().zip(want) {
            assert_eq!(rx.recv().unwrap().unwrap().sums, w);
        }
        let st = svc.stats();
        assert_eq!(st.sliced_batches, 0, "below-threshold batches must not slice");
        assert_eq!(st.slice_tasks, 0);
        svc.shutdown();
    }

    #[test]
    fn parallel_grain_off_disables_slicing() {
        // GRAIN_OFF is the kill switch (0 now means auto): even a batch
        // that would slice at any real grain runs single-executor
        let (net, svc) = service(ServiceCfg {
            workers: 4,
            shards: 1,
            max_batch: 512,
            max_wait: Duration::from_millis(50),
            queue_depth: 1 << 12,
            parallel_grain: GRAIN_OFF,
            ..Default::default()
        });
        let mut rng = Rng::new(79);
        let mut pending = Vec::new();
        let mut want = Vec::new();
        for _ in 0..200 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            want.push(sim::eval(&net, &codes));
            pending.push(svc.submit(codes).unwrap());
        }
        for (rx, w) in pending.into_iter().zip(want) {
            assert_eq!(rx.recv().unwrap().unwrap().sums, w);
        }
        let st = svc.stats();
        assert_eq!(st.sliced_batches, 0);
        assert_eq!(st.slice_tasks, 0);
        svc.shutdown();
    }

    #[test]
    fn auto_grain_targets_half_millisecond_slices() {
        // pure function: 0.5 ms target over the observed per-row time,
        // clamped to [256, 8192], cold fallback 2048
        assert_eq!(auto_grain(0.0), AUTO_GRAIN_COLD, "empty reservoir means cold fallback");
        assert_eq!(auto_grain(f64::NAN), AUTO_GRAIN_COLD);
        assert_eq!(auto_grain(-1.0), AUTO_GRAIN_COLD);
        assert_eq!(auto_grain(1000.0), 500, "1 us/row -> 500 rows per half-ms slice");
        assert_eq!(auto_grain(125.0), 4000);
        assert_eq!(auto_grain(10.0), AUTO_GRAIN_MAX, "fast models clamp to the ceiling");
        assert_eq!(auto_grain(1e7), AUTO_GRAIN_MIN, "slow models clamp to the floor");
    }

    #[test]
    fn parallel_grain_auto_adapts_from_observed_row_time() {
        // auto mode end to end: a cold service uses the 2048 fallback (so
        // 300-row batches run unsliced and feed the timing reservoir);
        // seeding the reservoir with a deliberately slow per-row time then
        // drops the derived grain to the 256 floor, and the same service
        // starts slicing its large batches — bit-exact either way
        let (net, svc) = service(ServiceCfg {
            workers: 4,
            shards: 1,
            max_batch: 512,
            max_wait: Duration::from_millis(100),
            queue_depth: 1 << 12,
            parallel_grain: 0,
            ..Default::default()
        });
        let mut rng = Rng::new(80);
        let mut wave = |n: usize| {
            // precompute expectations so submission outruns max_wait and
            // full max_batch batches actually form
            let rows: Vec<Vec<u32>> =
                (0..n).map(|_| (0..4).map(|_| rng.below(16) as u32).collect()).collect();
            let want: Vec<Vec<i64>> = rows.iter().map(|r| sim::eval(&net, r)).collect();
            let pending: Vec<_> = rows.into_iter().map(|r| svc.submit(r).unwrap()).collect();
            for (rx, w) in pending.into_iter().zip(want) {
                assert_eq!(rx.recv().unwrap().unwrap().sums, w);
            }
        };
        wave(300);
        let st = svc.stats();
        assert_eq!(st.sliced_batches, 0, "cold auto grain falls back to 2048: no slicing");
        assert!(
            svc.shared.row_ns.lock().unwrap().len() >= 1,
            "unsliced compiled batches must feed the timing reservoir"
        );
        // teach the reservoir this model is slow (1 ms/row): the derived
        // grain clamps to the 256 floor, so a full 512-row batch crosses
        // the 2 * grain threshold. Heavy seeding keeps the running mean
        // pinned against dilution by real samples from tail batches.
        for _ in 0..64 {
            svc.shared.row_ns.lock().unwrap().push(1e6);
        }
        wave(600);
        let st = svc.stats();
        assert!(st.sliced_batches >= 1, "floor grain must slice full batches: {st:?}");
        assert!(st.slice_tasks >= 1);
        svc.shutdown();
    }

    #[test]
    fn slice_latch_counts_down_and_records_poison() {
        let latch = SliceLatch::new(2);
        assert!(!latch.done());
        latch.complete(true);
        assert!(!latch.done());
        latch.complete(false);
        assert!(latch.done());
        assert!(latch.poisoned());
        // wait on a completed latch returns immediately, not after timeout
        let t0 = Instant::now();
        latch.wait(Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn slice_latch_wakes_parked_waiter() {
        let latch = Arc::new(SliceLatch::new(1));
        let l2 = Arc::clone(&latch);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            l2.complete(true);
        });
        let start = Instant::now();
        while !latch.done() {
            latch.wait(Duration::from_millis(1));
            assert!(start.elapsed() < Duration::from_secs(5), "latch never completed");
        }
        assert!(!latch.poisoned());
        t.join().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let (net, svc) = service(ServiceCfg { workers: 4, ..Default::default() });
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let svc = Arc::clone(&svc);
            let net = Arc::clone(&net);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..50 {
                    let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                    let want = sim::eval(&net, &codes);
                    let got = svc.submit_blocking(codes).unwrap();
                    assert_eq!(got.sums, want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(Arc::try_unwrap(svc).ok().unwrap().stats().completed, 400);
    }

    #[test]
    fn wrong_width_request_rejected_at_admission() {
        let (net, svc) = service(ServiceCfg::default());
        assert!(matches!(svc.submit(vec![1, 2, 3]), Err(SubmitError::Invalid(_))));
        assert!(matches!(svc.submit(vec![1, 2, 3, 0, 0]), Err(SubmitError::Invalid(_))));
        // submit_blocking must return the width error, not retry it
        assert!(svc.submit_blocking(vec![0; 9]).is_err());
        // a well-formed neighbor is unaffected
        let codes = vec![1u32, 2, 3, 0];
        let resp = svc.submit_blocking(codes.clone()).unwrap();
        assert_eq!(resp.sums, sim::eval(&net, &codes));
        assert_eq!(svc.stats().completed, 1);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // zero workers can't drain; queue_depth 4 must reject the 5th+
        let ck = synthetic(&[2, 2], &[3, 6], 7);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(
            net,
            ServiceCfg { workers: 0, queue_depth: 4, ..Default::default() },
        );
        let mut oks = 0;
        let mut errs = 0;
        let mut rxs = Vec::new();
        for _ in 0..10 {
            match svc.submit(vec![0, 1]) {
                Ok(rx) => {
                    oks += 1;
                    rxs.push(rx);
                }
                Err(e) => {
                    assert_eq!(e, SubmitError::Backpressure);
                    errs += 1;
                }
            }
        }
        assert_eq!(oks, 4);
        assert_eq!(errs, 6);
        assert_eq!(svc.stats().rejected, 6);
    }

    #[test]
    fn affine_submit_spills_to_other_shards() {
        // 2 parked shards of depth 2 each: one client fills BOTH through
        // the spill path before seeing backpressure — capacity stays
        // work-conserving even though the client is affine to one shard
        let ck = synthetic(&[2, 2], &[3, 6], 7);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(
            net,
            ServiceCfg { workers: 0, shards: 2, queue_depth: 4, ..Default::default() },
        );
        assert_eq!(svc.cfg().shards, 2, "workers == 0 leaves shards unclamped");
        let mut oks = 0;
        let mut errs = 0;
        let mut rxs = Vec::new();
        for _ in 0..10 {
            match svc.submit(vec![0, 1]) {
                Ok(rx) => {
                    oks += 1;
                    rxs.push(rx);
                }
                Err(e) => {
                    assert_eq!(e, SubmitError::Backpressure);
                    errs += 1;
                }
            }
        }
        assert_eq!(oks, 4, "both shards' capacity admits before backpressure");
        assert_eq!(errs, 6);
        let st = svc.stats();
        assert_eq!(st.rejected, 6);
        assert_eq!(st.per_shard.len(), 2);
        assert_eq!(st.per_shard.iter().map(|s| s.admitted).sum::<u64>(), 4);
        assert!(st.per_shard.iter().all(|s| s.admitted == 2), "{:?}", st.per_shard);
        // pinned submission sees only its shard's (full) queue
        assert_eq!(svc.submit_to(0, vec![0, 1]).unwrap_err(), SubmitError::Backpressure);
    }

    #[test]
    fn shards_clamped_to_workers() {
        let (_, svc) = service(ServiceCfg { workers: 2, shards: 8, ..Default::default() });
        assert_eq!(svc.cfg().shards, 2);
        // still serves correctly after clamping
        let resp = svc.submit_blocking(vec![1, 2, 3, 0]).unwrap();
        assert!(!resp.sums.is_empty());
        svc.shutdown();
    }

    #[test]
    fn hot_swap_while_serving() {
        // paper §6: LUT updates during operation; in-flight batches keep
        // their snapshot, later requests see the new table
        let ck = synthetic(&[3, 2], &[3, 6], 99);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(Arc::clone(&net), ServiceCfg::default());
        let codes = vec![1u32, 2, 3];
        let before = svc.submit_blocking(codes.clone()).unwrap().sums;
        assert_eq!(before, sim::eval(&net, &codes));
        // swap neuron 0's first active edge to a constant table
        let p = net.layers[0].neurons[0].luts[0].input;
        let n_codes = 1usize << ck.bits[0];
        svc.swap_edge(0, 0, p, vec![999_999; n_codes]).unwrap();
        let after = svc.submit_blocking(codes.clone()).unwrap().sums;
        assert_ne!(before[0], after[0]);
        // invalid swaps rejected while serving
        assert!(svc.swap_edge(7, 0, 0, vec![0; n_codes]).is_err());
        svc.shutdown();
    }

    #[test]
    fn batching_aggregates() {
        let (_, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            queue_depth: 1024,
            ..Default::default()
        });
        let rxs: Vec<_> = (0..64).map(|_| svc.submit(vec![1, 2, 3, 0]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let stats = svc.stats();
        assert!(stats.mean_batch > 1.5, "mean batch {}", stats.mean_batch);
        svc.shutdown();
    }

    #[test]
    fn submit_blocking_errors_after_shutdown() {
        // regression: the old catch-all retry loop treated "service
        // stopped" as backpressure and spun forever
        let (_, svc) = service(ServiceCfg::default());
        assert!(!svc.is_stopped());
        svc.submit_blocking(vec![1, 2, 3, 0]).unwrap();
        svc.shutdown();
        assert!(svc.is_stopped());
        assert_eq!(svc.submit(vec![1, 2, 3, 0]).unwrap_err(), SubmitError::Stopped);
        let t = Instant::now();
        assert!(svc.submit_blocking(vec![1, 2, 3, 0]).is_err());
        assert!(
            t.elapsed() < Duration::from_secs(1),
            "submit_blocking kept retrying after shutdown ({:?})",
            t.elapsed()
        );
        // shutdown is idempotent
        svc.shutdown();
    }

    #[test]
    fn submit_blocking_parks_through_sustained_backpressure() {
        // tiny admission queue + concurrent blocking clients: every request
        // completes bit-exactly with the condvar-parked retry path (the old
        // sleep-spin is gone; liveness must not depend on it)
        let (net, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_micros(20),
            queue_depth: 2,
            ..Default::default()
        });
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let svc = Arc::clone(&svc);
            let net = Arc::clone(&net);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..50 {
                    let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                    let want = sim::eval(&net, &codes);
                    assert_eq!(svc.submit_blocking(codes).unwrap().sums, want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.stats().completed, 200);
        svc.shutdown();
    }

    #[test]
    fn batches_form_while_others_execute() {
        // pipelining witness: with both executors asleep inside a batch,
        // the dispatcher must keep forming batches (under the old
        // lock-convoy design, formation was serialized with execution and
        // nothing could form until a worker finished)
        let (_, svc) = service(ServiceCfg {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            queue_depth: 1024,
            exec_delay: Duration::from_millis(500),
            ..Default::default()
        });
        // 16 requests = 4 full batches; 2 execute (sleeping), 2 must form behind them
        let rxs: Vec<_> = (0..16).map(|_| svc.submit(vec![1, 2, 3, 0]).unwrap()).collect();
        std::thread::sleep(Duration::from_millis(200));
        let st = svc.stats();
        assert_eq!(st.completed, 0, "executors are still sleeping");
        assert!(
            st.batches >= 3,
            "dispatcher should pipeline formation past the 2 executing batches, formed {}",
            st.batches
        );
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn lone_request_flushes_after_max_wait_from_submission() {
        let (_, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(40),
            ..Default::default()
        });
        let t = Instant::now();
        let resp = svc.submit_blocking(vec![1, 2, 3, 0]).unwrap();
        // dispatched by the max_wait flush (not earlier), measured from
        // submission (not from some later collection start)
        assert!(resp.latency >= Duration::from_millis(30), "flushed early: {:?}", resp.latency);
        assert!(t.elapsed() < Duration::from_secs(2), "waited far past max_wait");
        svc.shutdown();
    }

    #[test]
    fn sharded_plane_bit_exact_with_consistent_stats() {
        // 3 shards, 4 executors, stealing on: responses stay bit-exact and
        // the aggregated snapshot equals its per-shard breakdown
        let (net, svc) = service(ServiceCfg {
            workers: 4,
            shards: 3,
            steal: true,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        });
        let mut rng = Rng::new(9);
        let mut pending = Vec::new();
        for i in 0..300 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(&net, &codes);
            // pin round-robin so every shard provably sees traffic
            pending.push((svc.submit_to(i % 3, codes).unwrap(), want));
        }
        for (rx, want) in pending {
            assert_eq!(rx.recv().unwrap().unwrap().sums, want);
        }
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.completed, 300);
        assert_eq!(st.per_shard.len(), 3);
        assert!(st.per_shard.iter().all(|s| s.admitted > 0 && s.batches > 0), "{:?}", st.per_shard);
        assert_eq!(st.per_shard.iter().map(|s| s.admitted).sum::<u64>(), 300);
        assert_eq!(st.batches, st.per_shard.iter().map(|s| s.batches).sum::<u64>());
        for s in &st.per_shard {
            assert_eq!(s.flush_full + s.flush_timeout + s.flush_disconnect, s.batches, "{s:?}");
        }
        // after a full drain, every formed batch was popped exactly once
        assert_eq!(st.local_pops + st.steals, st.batches);
        let ops_per_sample = st.opt.as_ref().expect("compiled default").ops_after;
        assert_eq!(st.fused_ops, 300 * ops_per_sample as u64);
        assert!(ops_per_sample <= net.n_luts());
    }

    #[test]
    fn work_stealing_rebalances_a_heavy_tailed_shard() {
        // deterministic heavy tail: every batch is slow (25 ms) and ALL of
        // them land on shard 0; with stealing the home-1 executor must pull
        // roughly half the work, with stealing off the single home-0
        // executor serializes it. Both wall clock and p99 must show it.
        let run = |steal: bool| {
            let (net, svc) = service(ServiceCfg {
                workers: 2,
                shards: 2,
                steal,
                max_batch: 1, // one request = one batch = one 25 ms unit
                max_wait: Duration::from_micros(10),
                exec_delay: Duration::from_millis(25),
                exec_delay_shard: Some(0),
                ..Default::default()
            });
            let codes = vec![1u32, 2, 3, 0];
            let want = sim::eval(&net, &codes);
            let t0 = Instant::now();
            let rxs: Vec<_> =
                (0..8).map(|_| svc.submit_to(0, codes.clone()).unwrap()).collect();
            for rx in rxs {
                assert_eq!(rx.recv().unwrap().unwrap().sums, want);
            }
            let wall = t0.elapsed();
            svc.shutdown();
            let st = svc.stats();
            assert_eq!(st.completed, 8);
            assert_eq!(st.per_shard[0].admitted, 8);
            assert_eq!(st.per_shard[1].admitted, 0);
            (wall, st)
        };
        let (wall_steal, st_steal) = run(true);
        let (wall_serial, st_serial) = run(false);
        assert!(st_steal.steals >= 1, "idle executor never stole: {st_steal:?}");
        assert_eq!(st_serial.steals, 0, "steal=off must not steal: {st_serial:?}");
        // 8 x 25 ms serial vs ~2x parallel: demand a conservative 25% win so
        // loaded CI runners still pass while a broken steal path cannot
        assert!(
            wall_steal.as_secs_f64() < 0.75 * wall_serial.as_secs_f64(),
            "stealing did not rebalance the hot shard: steal {wall_steal:?} vs serial {wall_serial:?}"
        );
        assert!(
            st_steal.latency_p99_us < 0.75 * st_serial.latency_p99_us,
            "p99 with stealing ({:.0} us) should beat no-steal ({:.0} us)",
            st_steal.latency_p99_us,
            st_serial.latency_p99_us
        );
    }

    #[test]
    fn single_shard_no_steal_keeps_pipeline_semantics() {
        // shards=1, steal=off is the PR-2/3 pipeline: submission-relative
        // max_wait, graceful shutdown drain, and typed submit errors all
        // hold on the degenerate configuration
        let cfg = ServiceCfg {
            workers: 2,
            shards: 1,
            steal: false,
            max_batch: 4,
            max_wait: Duration::from_millis(40),
            exec_delay: Duration::from_millis(10),
            ..Default::default()
        };
        // (a) lone request flushes on the submission-relative budget
        let (_, svc) = service(cfg);
        let t = Instant::now();
        let resp = svc.submit_blocking(vec![1, 2, 3, 0]).unwrap();
        assert!(resp.latency >= Duration::from_millis(30), "flushed early: {:?}", resp.latency);
        assert!(t.elapsed() < Duration::from_secs(2));
        svc.shutdown();
        // (b) shutdown drains everything already admitted
        let (net, svc) = service(cfg);
        let codes = vec![1u32, 2, 3, 0];
        let want = sim::eval(&net, &codes);
        let rxs: Vec<_> = (0..12).map(|_| svc.submit(codes.clone()).unwrap()).collect();
        svc.shutdown(); // immediately: admitted requests must still complete
        for rx in rxs {
            assert_eq!(
                rx.recv().unwrap().unwrap().sums,
                want,
                "admitted request lost in shutdown drain"
            );
        }
        let st = svc.stats();
        assert_eq!(st.completed, 12);
        // the flush reasons partition the batch count even when shutdown
        // flushed a partial batch via the disconnect path
        let s = &st.per_shard[0];
        assert_eq!(s.flush_full + s.flush_timeout + s.flush_disconnect, s.batches, "{s:?}");
        assert_eq!(s.batches, st.batches);
        // (c) typed errors after shutdown, fail-fast
        assert_eq!(svc.submit(codes.clone()).unwrap_err(), SubmitError::Stopped);
        assert!(matches!(
            svc.submit(vec![1, 2]).unwrap_err(),
            SubmitError::Invalid(_) | SubmitError::Stopped
        ));
        let t = Instant::now();
        assert!(svc.submit_blocking(codes).is_err());
        assert!(t.elapsed() < Duration::from_secs(1));
        // (d) no steals can occur with one shard and stealing off
        assert_eq!(svc.stats().steals, 0);
        // (e) the supervision wrappers are free with fault injection off:
        // no panic, restart, shed, quarantine or injection counter moved,
        // so the degenerate configuration stays the PR-7 pipeline exactly
        let st = svc.stats();
        assert_eq!((st.exec_panics, st.respawns, st.failed), (0, 0, 0));
        assert_eq!((st.shed_expired, st.quarantine_drops, st.faults_injected), (0, 0, 0));
        assert!(st.per_shard.iter().all(|s| s.shed_expired == 0));
        assert!(st
            .per_tenant
            .iter()
            .all(|t| t.panics == 0 && t.failed == 0 && t.shed_expired == 0 && !t.quarantined));
    }

    #[test]
    fn supervised_executor_survives_panics_and_fails_only_its_batch() {
        // every 3rd batch is poisoned: its request gets a typed Failed
        // reply, the lone worker survives all 10 panics, and the other 20
        // requests stay bit-exact. workers=1 + max_batch=1 make the
        // injection slots deterministic (execution order == formation
        // order, one request per batch).
        let (net, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::from_micros(10),
            faults: FaultPlan { panic_every: 3, ..Default::default() },
            ..Default::default()
        });
        let mut rng = Rng::new(88);
        let mut pending = Vec::new();
        for _ in 0..30 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(&net, &codes);
            pending.push((svc.submit(codes).unwrap(), want));
        }
        let (mut ok, mut failed) = (0u64, 0u64);
        for (rx, want) in pending {
            match rx.recv().unwrap() {
                Ok(resp) => {
                    assert_eq!(resp.sums, want, "non-faulted rows stay bit-exact");
                    ok += 1;
                }
                Err(SubmitError::Failed) => failed += 1,
                Err(e) => panic!("unexpected reply error: {e}"),
            }
        }
        svc.shutdown();
        assert_eq!((ok, failed), (20, 10));
        let st = svc.stats();
        assert_eq!(st.completed, 20);
        assert_eq!(st.failed, 10);
        assert_eq!(st.exec_panics, 10);
        assert_eq!(st.respawns, 10);
        assert_eq!(st.faults_injected, 10);
        // every admitted request got exactly one typed outcome
        let admitted: u64 = st.per_shard.iter().map(|s| s.admitted).sum();
        assert_eq!(st.completed + st.failed + st.shed_expired + st.dropped, admitted);
        let t = &st.per_tenant[0];
        assert_eq!((t.failed, t.panics), (10, 10));
        assert_eq!(t.completed, 20);
    }

    #[test]
    fn expired_requests_shed_at_formation_with_typed_replies() {
        // one live request plus three whose 5 ms deadline lapses inside
        // the 40 ms formation wait: the batch executes the survivor and
        // each stale request gets a typed Expired reply
        let (net, svc) = service(ServiceCfg {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(40),
            ..Default::default()
        });
        let codes = vec![1u32, 2, 3, 0];
        let want = sim::eval(&net, &codes);
        let live = svc.submit(codes.clone()).unwrap();
        let stale: Vec<_> =
            (0..3).map(|_| svc.submit_deadline(codes.clone(), Some(5_000)).unwrap()).collect();
        assert_eq!(live.recv().unwrap().unwrap().sums, want);
        for rx in stale {
            assert_eq!(rx.recv().unwrap().unwrap_err(), SubmitError::Expired);
        }
        // a generous deadline is not shed
        let rx = svc.submit_deadline(codes.clone(), Some(5_000_000)).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().sums, want);
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.completed, 2);
        assert_eq!(st.shed_expired, 3);
        assert_eq!(st.per_shard[0].shed_expired, 3);
        let admitted: u64 = st.per_shard.iter().map(|s| s.admitted).sum();
        assert_eq!(st.completed + st.failed + st.shed_expired + st.dropped, admitted);
        let t = &st.per_tenant[0];
        assert_eq!((t.shed_expired, t.completed), (3, 2));
    }

    #[test]
    fn quarantine_trips_half_opens_and_recovers_with_cotenant_isolation() {
        let net_a = build_net(&[4, 3, 2], &[4, 5, 6], 2024);
        let net_b = build_net(&[6, 4, 3], &[3, 5, 6], 777);
        let reg = Arc::new(ModelRegistry::new(OptLevel::default()));
        let a = reg.load("a", Arc::clone(&net_a)).unwrap();
        let b = reg.load("b", Arc::clone(&net_b)).unwrap();
        // poison ONLY tenant a's batches, and only the first two
        let svc = Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg {
                workers: 1,
                max_batch: 1,
                max_wait: Duration::from_micros(10),
                faults: FaultPlan {
                    panic_every: 1,
                    panic_budget: 2,
                    panic_model: Some(a),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let ta = reg.resolve(a).unwrap();
        ta.quarantine_policy(2, Duration::from_millis(40));
        let codes_a = vec![1u32, 2, 3, 0];
        let codes_b = vec![1u32, 2, 3, 0, 1, 2];
        // two poisoned batches back to back: strike, strike -> OPEN
        for _ in 0..2 {
            let rx = svc.submit_model(a, codes_a.clone()).unwrap();
            assert_eq!(rx.recv().unwrap().unwrap_err(), SubmitError::Failed);
        }
        // quarantined: typed rejection without consuming admission capacity
        assert!(matches!(
            svc.submit_model(a, codes_a.clone()).unwrap_err(),
            SubmitError::Quarantined(_)
        ));
        // co-tenant b is untouched throughout
        let got = svc.submit_blocking_model(b, codes_b.clone()).unwrap();
        assert_eq!(got.sums, sim::eval(&net_b, &codes_b));
        // window elapses -> half-open: the probe admission runs clean
        // (the fault budget is spent) and closes the breaker
        std::thread::sleep(Duration::from_millis(80));
        let rx = svc.submit_model(a, codes_a.clone()).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().sums, sim::eval(&net_a, &codes_a));
        // recovered: subsequent traffic flows
        let got = svc.submit_blocking_model(a, codes_a.clone()).unwrap();
        assert_eq!(got.sums, sim::eval(&net_a, &codes_a));
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.exec_panics, 2);
        assert_eq!(st.failed, 2);
        assert_eq!(st.faults_injected, 2);
        assert_eq!(st.quarantine_drops, 1);
        let sa = st.per_tenant.iter().find(|t| t.name == "a").unwrap();
        assert_eq!((sa.panics, sa.failed, sa.quarantine_drops), (2, 2, 1));
        assert!(!sa.quarantined, "breaker closed after the clean probe");
        assert_eq!(sa.completed, 2);
        let sb = st.per_tenant.iter().find(|t| t.name == "b").unwrap();
        assert_eq!((sb.completed, sb.failed, sb.panics), (1, 0, 0));
        let admitted: u64 = st.per_shard.iter().map(|s| s.admitted).sum();
        assert_eq!(st.completed + st.failed + st.shed_expired + st.dropped, admitted);
    }

    #[test]
    fn optimized_serving_is_bit_exact_and_reports() {
        // a heavily pruned model (constant + duplicate tables) served at
        // both pass levels: responses stay bit-exact with sim on the
        // ORIGINAL netlist, and the Full level reports its reductions
        let mut ck = synthetic(&[6, 5, 3], &[4, 4, 6], 404);
        let n_codes = 1usize << ck.bits[0];
        let l = &mut ck.layers[0];
        let dup: Vec<i64> = (0..n_codes as i64).map(|i| i * 37 - 100).collect();
        for q in 0..l.d_out {
            // one constant and one duplicate column per neuron row
            l.mask[q * l.d_in] = true;
            l.table[q * l.d_in] = Some(vec![500 + q as i64; n_codes]);
            l.mask[q * l.d_in + 1] = true;
            l.table[q * l.d_in + 1] = Some(dup.clone());
        }
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        for level in [OptLevel::Full, OptLevel::None, OptLevel::Lossy(0)] {
            let svc = Service::start(
                Arc::clone(&net),
                ServiceCfg { workers: 2, opt: level, ..Default::default() },
            );
            let mut rng = Rng::new(7);
            let mut pending = Vec::new();
            for _ in 0..120 {
                let codes: Vec<u32> = (0..6).map(|_| rng.below(16) as u32).collect();
                let want = sim::eval(&net, &codes);
                pending.push((svc.submit(codes).unwrap(), want));
            }
            for (rx, want) in pending {
                assert_eq!(rx.recv().unwrap().unwrap().sums, want, "{level:?}");
            }
            let st = svc.stats();
            let opt = st.opt.as_ref().expect("compiled backend surfaces its report");
            assert_eq!(opt.level, level);
            match level {
                OptLevel::Full => {
                    assert!(opt.folded_edges >= 5, "{opt:?}");
                    assert!(opt.ops_after < opt.ops_before, "{opt:?}");
                    assert!(opt.table_bytes_after < opt.table_bytes_before, "{opt:?}");
                    assert!(opt.lossy.is_none(), "exact levels carry no lossy block");
                }
                OptLevel::None => {
                    assert_eq!(opt.ops_after, opt.ops_before);
                    assert_eq!(opt.ops_before, net.n_luts());
                    assert!(opt.lossy.is_none());
                }
                OptLevel::Lossy(_) => {
                    // budget 0 rides the Full pipeline (bit-exact, proven
                    // above by the response assertions) but still surfaces
                    // a lossy report — with zero actions and a zero bound
                    assert!(opt.folded_edges >= 5, "{opt:?}");
                    assert!(opt.ops_after < opt.ops_before, "{opt:?}");
                    let l = opt.lossy.as_ref().expect("lossy level surfaces its report");
                    assert_eq!(l.budget, 0);
                    assert_eq!(l.shared_tables + l.affine_folds + l.tightened_layers, 0);
                    assert_eq!(l.worst_case_bound, 0);
                }
            }
            assert_eq!(st.fused_ops, 120 * opt.ops_after as u64, "{level:?}");
            svc.shutdown();
        }
    }

    #[test]
    fn lossy_serving_stays_within_bound_and_reports() {
        // a checkpoint with deliberate near-duplicate tables served at a
        // real budget: responses may drift from the ORIGINAL netlist's
        // sim, but never past the compiled-in worst-case bound — the same
        // tolerance the debug cross-check in execute_batch enforces on
        // every batch (this test would hang on a poisoned batch if that
        // check still demanded equality) — and the lossy report reaches
        // ServiceStats with its actions counted
        let mut ck = synthetic(&[6, 5, 3], &[4, 4, 6], 909);
        prunify(&mut ck, 15, 10, 3);
        nearify(&mut ck, 100, 3, 11);
        let tables = lut::from_checkpoint(&ck);
        let net = Arc::new(Netlist::build(&ck, &tables, 2));
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg { workers: 2, opt: OptLevel::Lossy(8), ..Default::default() },
        );
        let mut rng = Rng::new(17);
        let mut pending = Vec::new();
        for _ in 0..120 {
            let codes: Vec<u32> = (0..6).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(&net, &codes);
            pending.push((svc.submit(codes).unwrap(), want));
        }
        let got: Vec<(Vec<i64>, Vec<i64>)> = pending
            .into_iter()
            .map(|(rx, want)| (rx.recv().unwrap().unwrap().sums, want))
            .collect();
        let st = svc.stats();
        let opt = st.opt.as_ref().expect("compiled backend surfaces its report");
        assert_eq!(opt.level, OptLevel::Lossy(8));
        let l = opt.lossy.as_ref().expect("nonzero budget surfaces a lossy report");
        assert_eq!(l.budget, 8);
        assert!(l.shared_tables >= 1, "nearified twins (2*amp <= budget) must merge: {l:?}");
        for (sums, want) in &got {
            assert_eq!(sums.len(), want.len());
            for (g, w) in sums.iter().zip(want) {
                assert!(
                    (g - w).abs() <= l.worst_case_bound,
                    "{g} vs sim {w} exceeds bound {}",
                    l.worst_case_bound
                );
            }
        }
        svc.shutdown();
    }

    #[test]
    fn latency_tracking_is_bounded() {
        // more requests than the reservoir retains: quantiles stay sane
        let (_, svc) = service(ServiceCfg {
            workers: 2,
            max_batch: 64,
            max_wait: Duration::from_micros(10),
            queue_depth: 1 << 14,
            ..Default::default()
        });
        let mut pending = Vec::new();
        for _ in 0..2 * LATENCY_RESERVOIR {
            loop {
                match svc.submit(vec![1, 2, 3, 0]) {
                    Ok(rx) => {
                        pending.push(rx);
                        break;
                    }
                    Err(SubmitError::Backpressure) => {
                        for rx in pending.drain(..) {
                            rx.recv().unwrap().unwrap();
                        }
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let st = svc.stats();
        assert_eq!(st.completed, 2 * LATENCY_RESERVOIR as u64);
        assert!(st.latency_p50_us.is_finite() && st.latency_p50_us > 0.0);
        assert!(st.latency_p90_us >= st.latency_p50_us);
        assert!(st.latency_p99_us >= st.latency_p90_us);
        svc.shutdown();
    }

    // -- multi-tenant registry serving -----------------------------------

    fn build_net(dims: &[usize], bits: &[u32], seed: u64) -> Arc<Netlist> {
        let ck = synthetic(dims, bits, seed);
        let tables = lut::from_checkpoint(&ck);
        Arc::new(Netlist::build(&ck, &tables, 2))
    }

    #[test]
    fn single_tenant_service_degenerates_to_default_tenant() {
        // the N=1 registry IS the pre-registry plane: one "default"
        // tenant whose counters equal the service totals at quiescence
        let (net, svc) = service(ServiceCfg::default());
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(&net, &codes);
            assert_eq!(svc.submit_blocking(codes).unwrap().sums, want);
        }
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.per_tenant.len(), 1);
        let t = &st.per_tenant[0];
        assert_eq!(t.name, "default");
        assert_eq!(t.id, ModelId::DEFAULT.raw());
        assert!(!t.retired);
        assert_eq!(t.completed, st.completed);
        assert_eq!(t.admitted, st.per_shard.iter().map(|s| s.admitted).sum::<u64>());
        assert_eq!(t.batches, st.batches);
        assert_eq!(t.mean_batch, st.mean_batch);
        assert_eq!(t.dropped, 0);
        assert_eq!(t.quota_drops, 0);
        assert_eq!(t.inflight, 0, "quota gauge drains with the plane");
        assert!(t.latency_p50_us > 0.0);
        assert_eq!(st.quota_drops, 0);
    }

    #[test]
    fn multi_tenant_routing_is_bit_exact_per_tenant() {
        // two tenants with DIFFERENT geometries behind one plane: every
        // response must come from the tenant the request named
        let net_a = build_net(&[4, 3, 2], &[4, 5, 6], 2024);
        let net_b = build_net(&[6, 4, 3], &[3, 5, 6], 777);
        let reg = Arc::new(ModelRegistry::new(OptLevel::default()));
        let a = reg.load("a", Arc::clone(&net_a)).unwrap();
        let b = reg.load("b", Arc::clone(&net_b)).unwrap();
        let svc = Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg { workers: 4, shards: 2, ..Default::default() },
        );
        let mut rng = Rng::new(11);
        let mut pending = Vec::new();
        for i in 0..120 {
            let (model, net, d, bits) =
                if i % 2 == 0 { (a, &net_a, 4, 16) } else { (b, &net_b, 6, 8) };
            let codes: Vec<u32> = (0..d).map(|_| rng.below(bits) as u32).collect();
            let want = sim::eval(net, &codes);
            pending.push((svc.submit_model(model, codes).unwrap(), want));
        }
        for (rx, want) in pending {
            assert_eq!(rx.recv().unwrap().unwrap().sums, want);
        }
        // width checks are per-tenant: a's width is Invalid on b
        assert!(matches!(svc.submit_model(b, vec![0; 4]), Err(SubmitError::Invalid(_))));
        // unknown ids are a typed, terminal error
        assert!(matches!(
            svc.submit_model(ModelId::from_raw(99), vec![0; 4]),
            Err(SubmitError::UnknownModel(_))
        ));
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.completed, 120);
        assert_eq!(st.per_tenant.len(), 2);
        for t in &st.per_tenant {
            assert_eq!(t.completed, 60, "{t:?}");
            assert!(t.batches >= 1);
            assert!(t.latency_p99_us >= t.latency_p50_us);
        }
        assert_eq!(st.per_tenant.iter().map(|t| t.completed).sum::<u64>(), st.completed);
        assert_eq!(st.per_tenant.iter().map(|t| t.batches).sum::<u64>(), st.batches);
        assert_eq!(
            st.per_tenant.iter().map(|t| t.admitted).sum::<u64>(),
            st.per_shard.iter().map(|s| s.admitted).sum::<u64>()
        );
    }

    #[test]
    fn quota_caps_in_flight_per_tenant() {
        // zero workers: admitted requests never drain, so the quota gauge
        // saturates deterministically; the unlimited neighbor is untouched
        let reg = Arc::new(ModelRegistry::new(OptLevel::default()));
        let q = reg.load_with_quota("q", build_net(&[2, 2], &[3, 6], 7), 3).unwrap();
        let free = reg.load("free", build_net(&[2, 2], &[3, 6], 8)).unwrap();
        let svc = Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg { workers: 0, queue_depth: 64, ..Default::default() },
        );
        let mut rxs = Vec::new();
        let mut drops = 0;
        for _ in 0..5 {
            match svc.submit_model(q, vec![0, 1]) {
                Ok(rx) => rxs.push(rx),
                Err(e) => {
                    assert_eq!(e, SubmitError::Backpressure);
                    drops += 1;
                }
            }
        }
        assert_eq!(rxs.len(), 3);
        assert_eq!(drops, 2);
        for _ in 0..5 {
            rxs.push(svc.submit_model(free, vec![1, 0]).unwrap());
        }
        let st = svc.stats();
        assert_eq!(st.quota_drops, 2);
        assert_eq!(st.rejected, 0, "quota drops are not queue backpressure");
        let tq = st.per_tenant.iter().find(|t| t.name == "q").unwrap();
        assert_eq!(tq.quota_drops, 2);
        assert_eq!(tq.inflight, 3);
        assert_eq!(tq.admitted, 3);
        let tf = st.per_tenant.iter().find(|t| t.name == "free").unwrap();
        assert_eq!(tf.quota_drops, 0);
        assert_eq!(tf.admitted, 5);
        // shutdown discards the parked requests; the RAII guards must
        // drain the in-flight gauges with them
        svc.shutdown();
        drop(rxs);
        let st = svc.stats();
        assert!(st.per_tenant.iter().all(|t| t.inflight == 0), "{:?}", st.per_tenant);
    }

    #[test]
    fn canary_accounting_is_exact_and_bit_exact() {
        // phase 1: canary == primary at 50% over 100 rows -> EXACTLY 50
        // canaried rows, 100% agreement, responses bit-exact either way
        let net = build_net(&[4, 3, 2], &[4, 5, 6], 2024);
        let reg = Arc::new(ModelRegistry::new(OptLevel::default()));
        let m = reg.load("m", Arc::clone(&net)).unwrap();
        reg.set_canary("m", Arc::clone(&net), 50).unwrap();
        let svc = Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg { workers: 2, shards: 2, ..Default::default() },
        );
        let mut rng = Rng::new(21);
        let mut pending = Vec::new();
        for _ in 0..100 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(&net, &codes);
            pending.push((svc.submit_model(m, codes).unwrap(), want));
        }
        for (rx, want) in pending {
            assert_eq!(rx.recv().unwrap().unwrap().sums, want);
        }
        let st = svc.stats();
        let t = &st.per_tenant[0];
        assert_eq!(t.canary_rows, 50, "50% of 100 rows, exactly");
        assert_eq!(t.canary_agree, 50, "identical checkpoints always agree");
        assert_eq!(t.canary_agreement, 1.0);
        // phase 2: a DIFFERENT same-geometry checkpoint at 100% — every
        // row is answered by the canary, bit-exact with ITS netlist
        let net2 = build_net(&[4, 3, 2], &[4, 5, 6], 4242);
        reg.set_canary("m", Arc::clone(&net2), 100).unwrap();
        let mut pending = Vec::new();
        for _ in 0..40 {
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(&net2, &codes);
            pending.push((svc.submit_model(m, codes).unwrap(), want));
        }
        for (rx, want) in pending {
            assert_eq!(rx.recv().unwrap().unwrap().sums, want, "100% canary answers from net2");
        }
        let st = svc.stats();
        let t = &st.per_tenant[0];
        assert_eq!(t.canary_rows, 90, "50 from phase 1 + 40 from phase 2");
        assert!(t.canary_agree >= 50 && t.canary_agree <= 90);
        // clearing stops the split; counters freeze
        reg.clear_canary("m").unwrap();
        let codes = vec![1u32, 2, 3, 0];
        let got = svc.submit_blocking_model(m, codes.clone()).unwrap();
        assert_eq!(got.sums, sim::eval(&net, &codes));
        assert_eq!(svc.stats().per_tenant[0].canary_rows, 90);
        svc.shutdown();
    }

    #[test]
    fn registry_load_unload_swap_under_concurrent_traffic() {
        let net_a = build_net(&[4, 3, 2], &[4, 5, 6], 2024);
        let reg = Arc::new(ModelRegistry::new(OptLevel::default()));
        let a = reg.load("a", Arc::clone(&net_a)).unwrap();
        let svc = Arc::new(Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg { workers: 4, shards: 2, ..Default::default() },
        ));
        // background clients hammer tenant "a" throughout the churn
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let svc = Arc::clone(&svc);
            let net = Arc::clone(&net_a);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(300 + t);
                for _ in 0..60 {
                    let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                    let want = sim::eval(&net, &codes);
                    assert_eq!(svc.submit_blocking_model(a, codes).unwrap().sums, want);
                }
            }));
        }
        // meanwhile: load a second tenant, serve it, intern, unload it
        let net_b = build_net(&[6, 4, 3], &[3, 5, 6], 777);
        let b = reg.load("b", Arc::clone(&net_b)).unwrap();
        let mut rng = Rng::new(99);
        for _ in 0..20 {
            let codes: Vec<u32> = (0..6).map(|_| rng.below(8) as u32).collect();
            let want = sim::eval(&net_b, &codes);
            assert_eq!(svc.submit_blocking_model(b, codes).unwrap().sums, want);
        }
        let arena = reg.reintern();
        assert_eq!(arena.programs, 2);
        reg.unload("b").unwrap();
        assert!(matches!(svc.submit_model(b, vec![0; 6]), Err(SubmitError::UnknownModel(_))));
        // swap "a" wholesale to a different-geometry model mid-traffic is
        // NOT safe for the asserting clients above, so swap after joining
        for h in handles {
            h.join().unwrap();
        }
        reg.swap("a", Arc::clone(&net_b)).unwrap();
        let codes: Vec<u32> = vec![1, 2, 3, 0, 1, 2];
        let got = svc.submit_blocking_model(a, codes.clone()).unwrap();
        assert_eq!(got.sums, sim::eval(&net_b, &codes));
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.completed, 4 * 60 + 20 + 1);
        let tb = st.per_tenant.iter().find(|t| t.name == "b").unwrap();
        assert!(tb.retired, "unloaded tenant keeps frozen history");
        assert_eq!(tb.completed, 20);
        assert_eq!(st.per_tenant.iter().map(|t| t.completed).sum::<u64>(), st.completed);
    }

    #[test]
    fn interleaved_tenants_form_single_tenant_batches() {
        // alternate two tenants through ONE shard's dispatcher: the DRR
        // collector must never mix tenants in a batch (execute_batch
        // debug_asserts it — a mixed batch would panic the worker and hang
        // this test), and the per-tenant batch counters must partition the
        // service total. Deterministic starvation coverage for the DRR
        // rotation itself lives in batcher::tests.
        let net_a = build_net(&[4, 3, 2], &[4, 5, 6], 1);
        let net_b = build_net(&[4, 3, 2], &[4, 5, 6], 2);
        let reg = Arc::new(ModelRegistry::new(OptLevel::default()));
        let a = reg.load("a", Arc::clone(&net_a)).unwrap();
        let b = reg.load("b", Arc::clone(&net_b)).unwrap();
        let svc = Service::start_registry(
            Arc::clone(&reg),
            ServiceCfg {
                workers: 2,
                shards: 1,
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
        );
        let mut rng = Rng::new(5);
        let mut pending = Vec::new();
        for i in 0..80 {
            let (model, net) = if i % 2 == 0 { (a, &net_a) } else { (b, &net_b) };
            let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
            let want = sim::eval(net, &codes);
            pending.push((svc.submit_to_model(0, model, codes).unwrap(), want));
        }
        for (rx, want) in pending {
            assert_eq!(rx.recv().unwrap().unwrap().sums, want);
        }
        svc.shutdown();
        let st = svc.stats();
        assert_eq!(st.completed, 80);
        // single-tenant batches: each tenant's 40 rows need >= 5 batches of
        // <= max_batch, and the two breakdowns partition the total exactly
        let ta = st.per_tenant.iter().find(|t| t.name == "a").unwrap();
        let tb = st.per_tenant.iter().find(|t| t.name == "b").unwrap();
        assert_eq!((ta.completed, tb.completed), (40, 40));
        assert!(ta.batches >= 5 && tb.batches >= 5, "{ta:?} {tb:?}");
        assert!(ta.mean_batch <= 8.0 && tb.mean_batch <= 8.0);
        assert_eq!(ta.batches + tb.batches, st.batches);
        assert_eq!(
            st.per_shard.iter().map(|s| s.batches).sum::<u64>(),
            st.batches,
            "one shard formed every batch"
        );
    }
}
