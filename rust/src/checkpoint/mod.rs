//! Typed checkpoint model loaded from the JSON exported by
//! `python/compile/export.py` (format `kanele-ckpt-v1`).
//!
//! The checkpoint carries everything the toolflow needs: spline parameters
//! (for L-LUT regeneration per the paper's flow), the authoritative tables
//! exported by the Python oracle (for bit-exact cross-language tests),
//! pruning masks, quantizer specs, the folded input preprocessing, and
//! oracle test vectors.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::fixed::Quantizer;
use crate::json::{self, Value};

/// One KAN layer's parameters + mask + exported truth tables.
#[derive(Clone, Debug)]
pub struct LayerCkpt {
    pub d_in: usize,
    pub d_out: usize,
    pub in_bits: u32,
    pub out_bits: u32,
    /// w_spline[q][p][k], f64 — row-major (d_out, d_in, n_basis).
    pub w_spline: Vec<f64>,
    pub n_basis: usize,
    /// w_base[q][p], f64 — (d_out, d_in).
    pub w_base: Vec<f64>,
    /// mask[q][p] — true = surviving edge.
    pub mask: Vec<bool>,
    /// Exported (authoritative) tables: table[q][p] is None for pruned edges,
    /// else 2^in_bits i64 entries.
    pub table: Vec<Option<Vec<i64>>>,
}

impl LayerCkpt {
    pub fn mask_at(&self, q: usize, p: usize) -> bool {
        self.mask[q * self.d_in + p]
    }

    pub fn table_at(&self, q: usize, p: usize) -> Option<&Vec<i64>> {
        self.table[q * self.d_in + p].as_ref()
    }

    pub fn w_base_at(&self, q: usize, p: usize) -> f64 {
        self.w_base[q * self.d_in + p]
    }

    pub fn w_spline_at(&self, q: usize, p: usize) -> &[f64] {
        let off = (q * self.d_in + p) * self.n_basis;
        &self.w_spline[off..off + self.n_basis]
    }

    /// Surviving edges in this layer.
    pub fn active_edges(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }
}

/// Folded input preprocessing: y = (x - shift) / span per feature.
#[derive(Clone, Debug)]
pub struct Preproc {
    pub shift: Vec<f64>,
    pub span: Vec<f64>,
}

impl Preproc {
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(self.shift.iter().zip(&self.span))
            .map(|(v, (s, p))| (v - s) / p)
            .collect()
    }
}

/// Oracle test vectors: input codes and expected final-layer i64 sums.
#[derive(Clone, Debug, Default)]
pub struct TestVectors {
    pub input_codes: Vec<Vec<u32>>,
    pub output_sums: Vec<Vec<i64>>,
}

/// Full checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub name: String,
    pub task: String, // classify | binary | regress
    pub grid_size: usize,
    pub order: usize,
    pub domain: (f64, f64),
    pub dims: Vec<usize>,
    pub bits: Vec<u32>,
    pub frac_bits: u32,
    pub prune_threshold: f64,
    pub preproc: Preproc,
    pub layers: Vec<LayerCkpt>,
    pub test_vectors: TestVectors,
}

impl Checkpoint {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Quantizer in front of layer `l` (l = 0 is the input quantizer).
    pub fn quantizer(&self, l: usize) -> Quantizer {
        Quantizer::new(self.bits[l], self.domain.0, self.domain.1)
    }

    /// Total surviving edges (Fig. 6b x-axis).
    pub fn active_edges(&self) -> usize {
        self.layers.iter().map(|l| l.active_edges()).sum()
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let doc = json::from_file(path)?;
        Self::from_json(&doc).with_context(|| format!("loading checkpoint {}", path.display()))
    }

    pub fn from_json(doc: &Value) -> Result<Checkpoint> {
        let format = doc.req_str("format")?;
        if format != "kanele-ckpt-v1" {
            bail!("unsupported checkpoint format {format:?}");
        }
        let dims: Vec<usize> = doc.req("dims")?.to_i64_vec()?.iter().map(|&v| v as usize).collect();
        let bits: Vec<u32> = doc.req("bits")?.to_i64_vec()?.iter().map(|&v| v as u32).collect();
        if bits.len() != dims.len() {
            bail!("bits/dims length mismatch: {} vs {}", bits.len(), dims.len());
        }
        let domain_arr = doc.req("domain")?.to_f64_vec()?;
        if domain_arr.len() != 2 || domain_arr[1] <= domain_arr[0] {
            bail!("bad domain {domain_arr:?}");
        }
        let grid_size = doc.req_i64("grid_size")? as usize;
        let order = doc.req_i64("order")? as usize;
        let n_basis = grid_size + order;

        let pre = doc.req("preproc")?;
        let preproc = Preproc {
            shift: pre.req("shift")?.to_f64_vec()?,
            span: pre.req("span")?.to_f64_vec()?,
        };
        if preproc.shift.len() != dims[0] || preproc.span.len() != dims[0] {
            bail!("preproc length != d_in");
        }
        if preproc.span.iter().any(|&s| s == 0.0 || !s.is_finite()) {
            bail!("preproc span has zero/non-finite entries");
        }

        let layers_json = doc.req_array("layers")?;
        if layers_json.len() != dims.len() - 1 {
            bail!("layer count {} != dims-1 {}", layers_json.len(), dims.len() - 1);
        }
        let mut layers = Vec::with_capacity(layers_json.len());
        for (l, lj) in layers_json.iter().enumerate() {
            let d_in = lj.req_i64("d_in")? as usize;
            let d_out = lj.req_i64("d_out")? as usize;
            if d_in != dims[l] || d_out != dims[l + 1] {
                bail!("layer {l} dims mismatch");
            }
            let in_bits = lj.req_i64("in_bits")? as u32;
            let out_bits = lj.req_i64("out_bits")? as u32;
            if in_bits != bits[l] || out_bits != bits[l + 1] {
                bail!("layer {l} bits mismatch");
            }

            let ws_rows = lj.req_array("w_spline")?;
            let mut w_spline = Vec::with_capacity(d_out * d_in * n_basis);
            for row in ws_rows {
                for cell in row.as_array().context("w_spline row")? {
                    let ks = cell.to_f64_vec()?;
                    if ks.len() != n_basis {
                        bail!("w_spline basis count {} != {}", ks.len(), n_basis);
                    }
                    w_spline.extend_from_slice(&ks);
                }
            }
            if w_spline.len() != d_out * d_in * n_basis {
                bail!("w_spline size mismatch in layer {l}");
            }

            let mut w_base = Vec::with_capacity(d_out * d_in);
            for row in lj.req_array("w_base")? {
                w_base.extend(row.to_f64_vec()?);
            }
            if w_base.len() != d_out * d_in {
                bail!("w_base size mismatch in layer {l}");
            }

            let mut mask = Vec::with_capacity(d_out * d_in);
            for row in lj.req_array("mask")? {
                for v in row.as_array().context("mask row")? {
                    mask.push(v.as_i64().context("mask entry")? != 0);
                }
            }
            if mask.len() != d_out * d_in {
                bail!("mask size mismatch in layer {l}");
            }

            let mut table = Vec::with_capacity(d_out * d_in);
            for row in lj.req_array("table")? {
                for cell in row.as_array().context("table row")? {
                    if cell.is_null() {
                        table.push(None);
                    } else {
                        let t = cell.to_i64_vec()?;
                        if t.len() != (1usize << in_bits) {
                            bail!("table size {} != 2^{in_bits} in layer {l}", t.len());
                        }
                        table.push(Some(t));
                    }
                }
            }
            if table.len() != d_out * d_in {
                bail!("table count mismatch in layer {l}");
            }
            // consistency: table presence must match the mask
            for (i, t) in table.iter().enumerate() {
                if t.is_some() != mask[i] {
                    bail!("table/mask inconsistency at edge {i} of layer {l}");
                }
            }

            layers.push(LayerCkpt {
                d_in,
                d_out,
                in_bits,
                out_bits,
                w_spline,
                n_basis,
                w_base,
                mask,
                table,
            });
        }

        let mut test_vectors = TestVectors::default();
        if let Some(tv) = doc.get("test_vectors") {
            for row in tv.req_array("input_codes")? {
                test_vectors
                    .input_codes
                    .push(row.to_i64_vec()?.iter().map(|&v| v as u32).collect());
            }
            for row in tv.req_array("output_sums")? {
                test_vectors.output_sums.push(row.to_i64_vec()?);
            }
            if test_vectors.input_codes.len() != test_vectors.output_sums.len() {
                bail!("test vector count mismatch");
            }
        }

        Ok(Checkpoint {
            name: doc.req_str("name")?.to_string(),
            task: doc.req_str("task")?.to_string(),
            grid_size,
            order,
            domain: (domain_arr[0], domain_arr[1]),
            dims,
            bits,
            frac_bits: doc.req_i64("frac_bits")? as u32,
            prune_threshold: doc.get("prune_threshold").and_then(|v| v.as_f64()).unwrap_or(0.0),
            preproc,
            layers,
            test_vectors,
        })
    }
}

/// Evaluation set exported alongside a checkpoint (`kanele-testset-v1`).
#[derive(Clone, Debug)]
pub struct TestSet {
    pub input_codes: Vec<Vec<u32>>,
    pub labels: Vec<i64>,
}

impl TestSet {
    pub fn load(path: &Path) -> Result<TestSet> {
        let doc = json::from_file(path)?;
        let format = doc.req_str("format")?;
        if format != "kanele-testset-v1" {
            bail!("unsupported testset format {format:?}");
        }
        let mut input_codes = Vec::new();
        for row in doc.req_array("input_codes")? {
            input_codes.push(row.to_i64_vec()?.iter().map(|&v| v as u32).collect());
        }
        let labels = doc.req("labels")?.to_i64_vec()?;
        if labels.len() != input_codes.len() {
            bail!("labels/inputs length mismatch");
        }
        Ok(TestSet { input_codes, labels })
    }
}

pub mod testutil {
    //! Synthetic checkpoint builder used across the crate's unit and
    //! integration tests (kept in the public API, `doc(hidden)`).
    use super::*;
    use crate::fixed;
    use crate::util::Rng;

    /// Build a small random (but internally consistent) checkpoint.
    /// Tables are generated from random per-edge functions, not splines —
    /// table semantics, not spline math, is what most tests exercise.
    pub fn synthetic(dims: &[usize], bits: &[u32], seed: u64) -> Checkpoint {
        assert_eq!(dims.len(), bits.len());
        let mut rng = Rng::new(seed);
        let (lo, hi) = (-4.0, 4.0);
        let frac_bits = 12u32;
        let grid_size = 4;
        let order = 2;
        let n_basis = grid_size + order;
        let mut layers = Vec::new();
        for l in 0..dims.len() - 1 {
            let (d_in, d_out) = (dims[l], dims[l + 1]);
            let n_codes = 1usize << bits[l];
            let mut mask = Vec::new();
            let mut table = Vec::new();
            let mut w_base = Vec::new();
            let mut w_spline = Vec::new();
            for _q in 0..d_out {
                for _p in 0..d_in {
                    let keep = rng.chance(0.8);
                    mask.push(keep);
                    w_base.push(rng.normal());
                    for _ in 0..n_basis {
                        w_spline.push(rng.normal() * 0.3);
                    }
                    if keep {
                        let amp = rng.range_f64(0.2, 1.5);
                        let phase = rng.range_f64(0.0, 6.28);
                        let t: Vec<i64> = (0..n_codes)
                            .map(|c| {
                                let x = lo + (hi - lo) * c as f64 / (n_codes - 1).max(1) as f64;
                                fixed::to_fixed(amp * (x + phase).sin(), frac_bits)
                            })
                            .collect();
                        table.push(Some(t));
                    } else {
                        table.push(None);
                    }
                }
            }
            layers.push(LayerCkpt {
                d_in,
                d_out,
                in_bits: bits[l],
                out_bits: bits[l + 1],
                w_spline,
                n_basis,
                w_base,
                mask,
                table,
            });
        }
        Checkpoint {
            name: "synthetic".into(),
            task: "classify".into(),
            grid_size,
            order,
            domain: (lo, hi),
            dims: dims.to_vec(),
            bits: bits.to_vec(),
            frac_bits,
            prune_threshold: 0.0,
            preproc: Preproc {
                shift: vec![0.0; dims[0]],
                span: vec![1.0; dims[0]],
            },
            layers,
            test_vectors: TestVectors::default(),
        }
    }

    /// Rewrite a checkpoint the way KANELE's prune-aware training leaves
    /// real ones: `const_pct`% of active edges collapse to constant tables
    /// (pruned-to-constant splines) and `dup_pct`% duplicate the first
    /// surviving table of their input column — same input + same content,
    /// so both the engine optimizer's table hash-consing and its CSE can
    /// fire. Deterministic for a given `seed`. Shared by the optimizer's
    /// unit/property tests and `benches/engine.rs`'s A/B section so the
    /// acceptance bars (>= 30% constant, >= 20% duplicate) are stated
    /// against one construction.
    pub fn prunify(ck: &mut Checkpoint, const_pct: usize, dup_pct: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        for layer in &mut ck.layers {
            let mut canon: Vec<Option<Vec<i64>>> = vec![None; layer.d_in];
            for q in 0..layer.d_out {
                for p in 0..layer.d_in {
                    let idx = q * layer.d_in + p;
                    let Some(t) = layer.table[idx].clone() else { continue };
                    let roll = rng.below(100) as usize;
                    if roll < const_pct {
                        let v = rng.range_i64(-3000, 3000);
                        layer.table[idx] = Some(vec![v; t.len()]);
                    } else if roll < const_pct + dup_pct {
                        match &canon[p] {
                            Some(c) => layer.table[idx] = Some(c.clone()),
                            None => canon[p] = Some(t),
                        }
                    } else if canon[p].is_none() {
                        canon[p] = Some(t);
                    }
                }
            }
        }
    }

    /// Rewrite `pct`% of each layer's active non-constant tables into
    /// *near*-duplicates of the layer's first surviving non-constant table:
    /// same content plus independent per-entry jitter drawn from
    /// `[-amp, amp]`. Bit-identical dedup cannot merge these, but lossy
    /// ε-clustering with budget >= 2*amp must (two jittered copies differ
    /// by at most `2*amp` elementwise, and each differs from the canon by
    /// at most `amp`). Constant tables are left alone so constant folding
    /// still sees them. Deterministic for a given `seed`; shared by the
    /// optimizer's lossy tests and `benches/engine.rs`'s lossy section.
    pub fn nearify(ck: &mut Checkpoint, pct: usize, amp: i64, seed: u64) {
        assert!(amp >= 1, "jitter amplitude must be at least 1 LSB");
        let mut rng = Rng::new(seed);
        for layer in &mut ck.layers {
            let is_const = |t: &[i64]| t.iter().all(|&v| v == t[0]);
            let canon: Option<Vec<i64>> = layer
                .table
                .iter()
                .flatten()
                .find(|t| !is_const(t))
                .cloned();
            let Some(canon) = canon else { continue };
            let mut seen_canon = false;
            for slot in layer.table.iter_mut() {
                let Some(t) = slot else { continue };
                if is_const(t) {
                    continue;
                }
                if !seen_canon && *t == canon {
                    seen_canon = true; // leave the representative itself alone
                    continue;
                }
                if rng.below(100) as usize >= pct {
                    continue;
                }
                *slot = Some(
                    canon.iter().map(|&v| v + rng.range_i64(-amp, amp)).collect(),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_checkpoint_consistent() {
        let ck = testutil::synthetic(&[4, 3, 2], &[4, 5, 6], 1);
        assert_eq!(ck.n_layers(), 2);
        assert_eq!(ck.layers[0].table.len(), 12);
        for l in &ck.layers {
            for (i, t) in l.table.iter().enumerate() {
                assert_eq!(t.is_some(), l.mask[i]);
                if let Some(t) = t {
                    assert_eq!(t.len(), 1 << l.in_bits);
                }
            }
        }
        assert!(ck.active_edges() > 0);
    }

    #[test]
    fn quantizer_accessor() {
        let ck = testutil::synthetic(&[2, 2], &[3, 8], 2);
        assert_eq!(ck.quantizer(0).bits, 3);
        assert_eq!(ck.quantizer(1).bits, 8);
    }

    #[test]
    fn rejects_bad_format() {
        let doc = crate::json::parse(r#"{"format": "nope"}"#).unwrap();
        assert!(Checkpoint::from_json(&doc).is_err());
    }
}
