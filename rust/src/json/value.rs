//! JSON value model. Integers and floats are distinct variants so i64 truth
//! tables survive round-trips beyond 2^53.

use std::collections::BTreeMap;

/// A JSON document node.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    /// BTreeMap keeps serialization deterministic (sorted keys).
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 9.0e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Required-field helpers with contextual errors — checkpoint loading
    /// uses these everywhere.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required field '{key}'"))
    }

    pub fn req_i64(&self, key: &str) -> anyhow::Result<i64> {
        self.req(key)?
            .as_i64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an integer"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    pub fn req_array(&self, key: &str) -> anyhow::Result<&[Value]> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))
    }

    /// Convert an array of numbers to Vec<f64>.
    pub fn to_f64_vec(&self) -> anyhow::Result<Vec<f64>> {
        self.as_array()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric element")))
            .collect()
    }

    /// Convert an array of integers to Vec<i64>.
    pub fn to_i64_vec(&self) -> anyhow::Result<Vec<i64>> {
        self.as_array()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|v| v.as_i64().ok_or_else(|| anyhow::anyhow!("non-integer element")))
            .collect()
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}

impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::Float(f)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience constructor for objects.
#[allow(dead_code)]
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = obj(vec![("x", Value::Int(3)), ("y", Value::Float(1.5))]);
        assert_eq!(v.req_i64("x").unwrap(), 3);
        assert_eq!(v.req_f64("y").unwrap(), 1.5);
        assert!(v.req_i64("z").is_err());
        assert!(v.req_str("x").is_err());
    }

    #[test]
    fn int_float_coercion() {
        assert_eq!(Value::Float(4.0).as_i64(), Some(4));
        assert_eq!(Value::Float(4.5).as_i64(), None);
        assert_eq!(Value::Int(4).as_f64(), Some(4.0));
    }
}
