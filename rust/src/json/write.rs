//! JSON serializer (compact form, deterministic key order via BTreeMap).

use super::value::Value;

/// Serialize a value to a compact JSON string.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => write_f64(*f, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Object(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_f64(f: f64, out: &mut String) {
    if f.is_finite() {
        if f.fract() == 0.0 && f.abs() < 1e15 {
            // keep a decimal point so it re-parses as float-compatible
            out.push_str(&format!("{f:.1}"));
        } else {
            // shortest round-trippable representation
            out.push_str(&format!("{f}"));
        }
    } else {
        // JSON has no Inf/NaN; emit null (matching python json.dumps default
        // would be an error; we choose null and assert finiteness upstream)
        out.push_str("null");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;
    use crate::json::value::obj;

    #[test]
    fn floats_reparse_as_floats() {
        let s = to_string(&Value::Float(2.0));
        assert_eq!(s, "2.0");
        assert!(matches!(parse(&s).unwrap(), Value::Float(_)));
    }

    #[test]
    fn object_key_order_deterministic() {
        let v = obj(vec![("b", Value::Int(1)), ("a", Value::Int(2))]);
        assert_eq!(to_string(&v), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn control_chars_escaped() {
        let s = to_string(&Value::Str("\u{1}".into()));
        assert_eq!(s, "\"\\u0001\"");
        assert_eq!(parse(&s).unwrap().as_str().unwrap(), "\u{1}");
    }
}
