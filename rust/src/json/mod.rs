//! Minimal JSON parser + serializer (substrate — `serde_json` is not in the
//! offline registry).
//!
//! Full RFC 8259 value model; strict parsing with byte-offset error
//! reporting. Numbers are kept as `f64` with an `i64` fast path so L-LUT
//! truth tables (large integer arrays) round-trip exactly.

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError, MAX_DEPTH};
pub use value::{obj, Value};
pub use write::to_string;

/// Parse a JSON file from disk.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\"", "1e-3"] {
            let v = parse(s).unwrap();
            let back = parse(&to_string(&v)).unwrap();
            assert_eq!(v, back, "{s}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, {"b": null, "c": [true, false]}], "d": "x\ny"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v, parse(&to_string(&v)).unwrap());
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
    }

    #[test]
    fn integers_exact() {
        let v = parse("[9007199254740993, -9007199254740993]").unwrap();
        // beyond f64's 2^53: must survive via the i64 representation
        let arr = v.as_array().unwrap();
        assert_eq!(arr[0].as_i64(), Some(9007199254740993));
        assert_eq!(arr[1].as_i64(), Some(-9007199254740993));
        assert_eq!(to_string(&v), "[9007199254740993,-9007199254740993]");
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\c\/d\b\f\n\r\tAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c/d\u{8}\u{c}\n\r\tA\u{e9}");
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,]", "{\"a\":}", "01", "nul", "\"\\q\"", "[1 2]", "1.2.3", "{\"a\" 1}"] {
            assert!(parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn nesting_bomb_rejected_not_stack_overflow() {
        // untrusted wire input: a deep container chain must come back as a
        // typed ParseError, not recurse the parser off the stack (an abort)
        for doc in [
            "[".repeat(100_000),
            "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1),
            "{\"a\":".repeat(100_000) + "1" + &"}".repeat(100_000),
        ] {
            let err = parse(&doc).unwrap_err();
            assert!(err.to_string().contains("nesting"), "{err}");
        }
        // documents at or under the limit still parse
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn fuzz_random_bytes_never_panic() {
        // the wire path feeds raw frame payloads straight into parse():
        // any byte soup must produce Ok or a typed error, never a panic
        prop::check("json-fuzz-bytes", 500, |g| {
            let n = g.usize_in(0, 512);
            let bytes: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let text = String::from_utf8_lossy(&bytes);
            let _ = parse(&text);
            Ok(())
        });
        // structured-ish soup: JSON punctuation biased so the parser's
        // container/str/number paths are actually reached
        prop::check("json-fuzz-punct", 500, |g| {
            const ALPHABET: &[u8] = b"{}[]\",:0123456789.eE+-truefalsn \\u";
            let n = g.usize_in(0, 256);
            let text: String =
                (0..n).map(|_| ALPHABET[g.usize_in(0, ALPHABET.len() - 1)] as char).collect();
            let _ = parse(&text);
            Ok(())
        });
    }

    #[test]
    fn prop_i64_roundtrip() {
        prop::check("json-i64-roundtrip", 200, |g| {
            let n = g.usize_in(0, 50);
            let xs = g.vec_i64(n, i64::MIN / 2, i64::MAX / 2);
            let v = Value::Array(xs.iter().map(|&x| Value::Int(x)).collect());
            let back = parse(&to_string(&v)).map_err(|e| e.to_string())?;
            let ys: Vec<i64> = back
                .as_array()
                .ok_or("not array")?
                .iter()
                .map(|v| v.as_i64().ok_or("not int".to_string()))
                .collect::<Result<_, _>>()?;
            if xs != ys {
                return Err(format!("{xs:?} != {ys:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_f64_roundtrip() {
        prop::check("json-f64-roundtrip", 200, |g| {
            let n = g.usize_in(0, 30);
            let xs = g.vec_f64(n, -1e9, 1e9);
            let v = Value::Array(xs.iter().map(|&x| Value::Float(x)).collect());
            let back = parse(&to_string(&v)).map_err(|e| e.to_string())?;
            for (i, x) in xs.iter().enumerate() {
                let y = back.as_array().unwrap()[i].as_f64().ok_or("not num")?;
                if (x - y).abs() > 1e-12 * x.abs().max(1.0) {
                    return Err(format!("{x} != {y}"));
                }
            }
            Ok(())
        });
    }
}
