//! Recursive-descent JSON parser with byte offsets in errors.

use std::collections::BTreeMap;
use std::fmt;

use super::value::Value;

/// Parse error with the byte offset where it occurred.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Maximum container nesting. The parser is recursive descent, so without
/// a bound a hostile document (`[[[[...`) drives the call stack as deep as
/// its byte length — a stack overflow aborts the process, which an
/// untrusted-input path (the network front end feeds wire frames straight
/// into [`parse`]) must never allow. 128 is far beyond any document this
/// repo produces and keeps worst-case stack use in the tens of KiB.
pub const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document (rejects trailing content).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, checked against [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal (expected '{word}')")))
        }
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err(format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        self.depth += 1;
        Ok(())
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: must be followed by \uXXXX low
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad code point"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad code point"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) => {
                    // re-decode UTF-8 multibyte sequences from the source
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid UTF-8 lead byte"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8 sequence"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part: 0 | [1-9][0-9]*
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    return Err(self.err("leading zero"));
                }
            }
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required after decimal point"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}
