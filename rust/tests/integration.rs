//! Integration tests across the full toolflow: checkpoint -> L-LUTs ->
//! netlist -> simulators -> synthesis -> reports, on real artifacts when
//! `make artifacts` has run and on synthetic checkpoints otherwise.

use std::sync::Arc;
use std::time::Duration;

use kanele::checkpoint::{testutil, Checkpoint, TestSet};
use kanele::coordinator::{Backend, ModelRegistry, Service, ServiceCfg, SubmitError};
use kanele::net::{Client, ErrorKind, NetCfg, NetError, NetServer, WireRequest, WireResponse};
use kanele::netlist::Netlist;
use kanele::util::Rng;
use kanele::{config, data, engine, lut, report, rl, sim, synth, vhdl};

fn artifact_ckpt(name: &str) -> Option<Checkpoint> {
    let p = config::ckpt_path(name);
    p.exists().then(|| Checkpoint::load(&p).expect("valid checkpoint"))
}

#[test]
fn moons_checkpoint_loads_and_verifies() {
    let Some(ck) = artifact_ckpt("moons") else {
        eprintln!("skipping (run make artifacts)");
        return;
    };
    assert_eq!(ck.dims, vec![2, 2, 1]);
    assert_eq!(ck.bits, vec![6, 5, 8]);
    // regeneration within 1 LSB of exported tables (libm exp tolerance)
    let (total, mismatched, maxdiff) = lut::compare_with_exported(&ck);
    assert!(total > 0);
    assert!(maxdiff <= 1, "max diff {maxdiff}");
    assert!(
        (mismatched as f64) < 0.01 * total as f64 + 2.0,
        "{mismatched}/{total} mismatched"
    );
}

#[test]
fn moons_netlist_bit_exact_vs_python_oracle() {
    let Some(ck) = artifact_ckpt("moons") else {
        eprintln!("skipping (run make artifacts)");
        return;
    };
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let tv = &ck.test_vectors;
    assert!(!tv.input_codes.is_empty());
    for (codes, want) in tv.input_codes.iter().zip(&tv.output_sums) {
        assert_eq!(&sim::eval(&net, codes), want);
    }
    // cycle-accurate pipeline agrees too
    let mut cs = sim::CycleSim::new(&net);
    let comps = cs.run_stream(&tv.input_codes);
    assert_eq!(comps.len(), tv.input_codes.len());
    for c in comps {
        assert_eq!(c.sums, tv.output_sums[c.id as usize]);
    }
    // ... and so does the compiled serving engine
    let prog = engine::compile(&net);
    assert_eq!(engine::run_batch(&prog, &tv.input_codes), tv.output_sums);
}

#[test]
fn compiled_engine_bit_exact_on_all_artifacts() {
    // engine::run_batch == sim::eval on every existing checkpoint artifact
    // (acceptance criterion of the compile→execute split)
    for exp in config::EXPERIMENTS {
        let Some(ck) = artifact_ckpt(exp.name) else { continue };
        let tables = lut::from_checkpoint(&ck);
        for n_add in [2usize, 4] {
            let net = Netlist::build(&ck, &tables, n_add);
            // the default lowering runs the optimizer; the 1:1 baseline
            // keeps one op per L-LUT — both must match sim bit for bit
            let prog = engine::compile(&net);
            let prog_none = engine::compile_with(&net, engine::OptLevel::None);
            assert_eq!(prog_none.n_ops(), net.n_luts(), "{}", exp.name);
            assert!(prog.n_ops() <= net.n_luts(), "{}", exp.name);
            let opt = prog.opt_report().expect("default lowering reports");
            assert_eq!(opt.ops_before, net.n_luts(), "{}", exp.name);
            let oracle = &ck.test_vectors.input_codes;
            let stream;
            let inputs: &[Vec<u32>] = if oracle.is_empty() {
                stream = data::random_code_stream(&ck, 256, 5);
                &stream
            } else {
                oracle
            };
            let want = sim::eval_batch(&net, inputs);
            assert_eq!(engine::run_batch(&prog, inputs), want, "{} (n_add {n_add})", exp.name);
            assert_eq!(
                engine::run_batch(&prog_none, inputs),
                want,
                "{} OptLevel::None (n_add {n_add})",
                exp.name
            );
            // the zero-alloc flat path (the coordinator's hot path) agrees
            // sample for sample on the narrowed-arena program
            let mut ex = engine::Executor::with_capacity(&prog, inputs.len());
            let mut flat = Vec::new();
            ex.run_batch_into(&prog, inputs, &mut flat);
            let want_flat: Vec<i64> = want.iter().flatten().copied().collect();
            assert_eq!(flat, want_flat, "{} flat outputs (n_add {n_add})", exp.name);
        }
    }
}

#[test]
fn any_available_dataset_full_flow() {
    // run the complete flow for every checkpoint artifact that exists
    for exp in config::EXPERIMENTS {
        let Some(ck) = artifact_ckpt(exp.name) else { continue };
        let tables = lut::from_checkpoint(&ck);
        for n_add in [2usize, 4] {
            let net = Netlist::build(&ck, &tables, n_add);
            let dev = synth::device_by_name(exp.device).unwrap();
            let r = synth::synthesize(&net, &dev);
            assert_eq!(r.brams, 0, "{}: LUT-native design must use no BRAM", exp.name);
            assert_eq!(r.dsps, 0, "{}: and no DSP", exp.name);
            assert!(r.fmax_mhz > 100.0);
            assert!(r.latency_cycles == net.latency_cycles());
            // paper's headline: everything fits its device
            assert!(r.fits, "{} does not fit {}", exp.name, exp.device);
        }
        // bit-exactness against the embedded oracle
        let net = Netlist::build(&ck, &tables, 2);
        for (codes, want) in ck
            .test_vectors
            .input_codes
            .iter()
            .zip(&ck.test_vectors.output_sums)
            .take(64)
        {
            assert_eq!(&sim::eval(&net, codes), want, "{}", exp.name);
        }
    }
}

#[test]
fn testset_metrics_match_training_claims() {
    // the netlist metric must be in the ballpark the Python trainer logged
    for (name, floor) in [("moons", 90.0), ("wine", 90.0), ("jsc_openml", 80.0)] {
        let Some(ck) = artifact_ckpt(name) else { continue };
        if !config::testset_path(name).exists() {
            continue;
        }
        let tables = lut::from_checkpoint(&ck);
        let net = Netlist::build(&ck, &tables, 2);
        let m = report::eval_metric(&ck, &net).unwrap();
        assert!(m > floor, "{name}: netlist metric {m} below {floor}");
    }
}

#[test]
fn serving_over_real_checkpoint() {
    let Some(ck) = artifact_ckpt("moons") else {
        eprintln!("skipping (run make artifacts)");
        return;
    };
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    // the compiled default backend and the interpreter must be
    // indistinguishable from the client side
    for backend in [Backend::Compiled, Backend::Interpreted] {
        let svc = Service::start(
            Arc::clone(&net),
            ServiceCfg {
                workers: 2,
                max_batch: 32,
                max_wait: Duration::from_micros(50),
                queue_depth: 4096,
                backend,
                ..Default::default()
            },
        );
        let stream = data::random_code_stream(&ck, 2000, 3);
        let mut pending = Vec::new();
        for codes in &stream {
            pending.push((codes.clone(), svc.submit(codes.clone()).unwrap()));
        }
        for (codes, rx) in pending {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.sums, sim::eval(&net, &codes), "{backend:?}");
        }
        assert_eq!(svc.stats().completed, 2000);
        svc.shutdown();
    }
}

#[test]
fn coordinator_pipeline_under_saturating_load() {
    // dispatcher/executor pipeline end to end: 8 concurrent clients
    // saturate a 4-executor service; every response is bit-exact, batches
    // actually aggregate, and after shutdown submission fails fast
    let ck = testutil::synthetic(&[4, 3, 2], &[4, 5, 6], 77);
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let svc = Arc::new(Service::start(
        Arc::clone(&net),
        ServiceCfg {
            workers: 4,
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            queue_depth: 1 << 12,
            ..Default::default()
        },
    ));
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let svc = Arc::clone(&svc);
        let net = Arc::clone(&net);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let mut pending = Vec::new();
            for _ in 0..500 {
                let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                let want = sim::eval(&net, &codes);
                loop {
                    match svc.submit(codes.clone()) {
                        Ok(rx) => {
                            pending.push((rx, want));
                            break;
                        }
                        Err(SubmitError::Backpressure) => {
                            std::thread::sleep(Duration::from_micros(10))
                        }
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
            }
            for (rx, want) in pending {
                assert_eq!(rx.recv().unwrap().unwrap().sums, want);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let st = svc.stats();
    assert_eq!(st.completed, 4000);
    assert!(st.batches >= 1);
    assert!(
        st.mean_batch > 2.0,
        "saturating load must aggregate batches, mean {}",
        st.mean_batch
    );
    // shutdown through a shared handle while clients still hold clones
    svc.shutdown();
    assert!(matches!(svc.submit(vec![0, 0, 0, 0]), Err(SubmitError::Stopped)));
    let t0 = std::time::Instant::now();
    assert!(svc.submit_blocking(vec![0, 0, 0, 0]).is_err());
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "submit_blocking must error after shutdown, not spin"
    );
}

#[test]
fn sharded_plane_under_saturating_load() {
    // the sharded admission + work-stealing plane end to end: 8 clients
    // pinned round-robin across 2 shards saturate a 4-executor pool;
    // every response is bit-exact, the aggregated stats snapshot is
    // consistent with its per-shard breakdown, and shutdown fails fast
    let ck = testutil::synthetic(&[4, 3, 2], &[4, 5, 6], 78);
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let svc = Arc::new(Service::start(
        Arc::clone(&net),
        ServiceCfg {
            workers: 4,
            shards: 2,
            steal: true,
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            queue_depth: 1 << 12,
            ..Default::default()
        },
    ));
    assert_eq!(svc.cfg().shards, 2);
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let svc = Arc::clone(&svc);
        let net = Arc::clone(&net);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let mut pending = Vec::new();
            for _ in 0..500 {
                let codes: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
                let want = sim::eval(&net, &codes);
                loop {
                    // pin so both shards provably see traffic
                    match svc.submit_to(t as usize % 2, codes.clone()) {
                        Ok(rx) => {
                            pending.push((rx, want));
                            break;
                        }
                        Err(SubmitError::Backpressure) => {
                            std::thread::sleep(Duration::from_micros(10))
                        }
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
            }
            for (rx, want) in pending {
                assert_eq!(rx.recv().unwrap().unwrap().sums, want);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    svc.shutdown();
    let st = svc.stats();
    assert_eq!(st.completed, 4000);
    assert_eq!(st.per_shard.len(), 2);
    assert!(
        st.per_shard.iter().all(|s| s.admitted > 0 && s.batches > 0),
        "both shards must carry traffic: {:?}",
        st.per_shard
    );
    assert_eq!(st.per_shard.iter().map(|s| s.admitted).sum::<u64>(), 4000);
    assert_eq!(st.batches, st.per_shard.iter().map(|s| s.batches).sum::<u64>());
    // after a full drain every formed batch was popped exactly once,
    // locally or via a steal
    assert_eq!(st.local_pops + st.steals, st.batches);
    assert!(st.mean_batch > 1.5, "saturating load must aggregate, mean {}", st.mean_batch);
    assert!(matches!(svc.submit(vec![0, 0, 0, 0]), Err(SubmitError::Stopped)));
}

#[test]
fn vhdl_bundle_for_real_model() {
    let Some(ck) = artifact_ckpt("moons") else {
        eprintln!("skipping (run make artifacts)");
        return;
    };
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let files = vhdl::emit_bundle(
        &net,
        Some((&ck.test_vectors.input_codes, &ck.test_vectors.output_sums)),
    );
    assert!(files.len() >= 2 + 2 * ck.n_layers());
    // every surviving edge's table is in some package
    let all_pkgs: String = files
        .iter()
        .filter(|f| f.name.contains("_pkg"))
        .map(|f| f.contents.as_str())
        .collect();
    assert_eq!(
        all_pkgs.matches("_ROM : ").count(),
        ck.active_edges(),
        "one ROM constant per active edge"
    );
}

#[test]
fn synthetic_flow_with_extreme_shapes() {
    // single-input, single-output, 1-bit codes
    let ck = testutil::synthetic(&[1, 1], &[1, 8], 99);
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let out0 = sim::eval(&net, &[0]);
    let out1 = sim::eval(&net, &[1]);
    assert_eq!(out0.len(), 1);
    // deep narrow network
    let ck2 = testutil::synthetic(&[2, 2, 2, 2, 2, 2], &[3, 3, 3, 3, 3, 4], 7);
    let tables2 = lut::from_checkpoint(&ck2);
    let net2 = Netlist::build(&ck2, &tables2, 2);
    let mut cs = sim::CycleSim::new(&net2);
    let inputs: Vec<Vec<u32>> = (0..8).map(|i| vec![i % 8, (i * 3) % 8]).collect();
    let comps = cs.run_stream(&inputs);
    assert_eq!(comps.len(), 8);
    for c in &comps {
        assert_eq!(c.sums, sim::eval(&net2, &inputs[c.id as usize]));
    }
    // compiled engine handles the extreme shapes identically
    assert_eq!(
        engine::run_batch(&engine::compile(&net), &[vec![0u32], vec![1u32]]),
        vec![out0, out1]
    );
    assert_eq!(
        engine::run_batch(&engine::compile(&net2), &inputs),
        sim::eval_batch(&net2, &inputs)
    );
}

#[test]
fn optimizer_pipeline_end_to_end_on_pruned_checkpoint() {
    // a checkpoint shaped like pruning-aware training left it (constant
    // columns, duplicate tables, a dead input) through the whole flow:
    // compile at both levels, serve the optimized default, stay bit-exact
    let mut ck = testutil::synthetic(&[5, 4, 3], &[4, 4, 6], 2026);
    let n_codes = 1usize << ck.bits[0];
    {
        let l = &mut ck.layers[0];
        let dup: Vec<i64> = (0..n_codes as i64).map(|i| i * 53 - 311).collect();
        for q in 0..l.d_out {
            l.mask[q * l.d_in] = true;
            l.table[q * l.d_in] = Some(vec![64 * (q as i64 + 1); n_codes]); // constants
            l.mask[q * l.d_in + 1] = true;
            l.table[q * l.d_in + 1] = Some(dup.clone()); // shared content
            l.mask[q * l.d_in + 2] = false; // input 2 feeds nothing
            l.table[q * l.d_in + 2] = None;
        }
    }
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let prog = engine::compile(&net);
    let prog_none = engine::compile_with(&net, engine::OptLevel::None);
    let opt = prog.opt_report().unwrap();
    assert!(opt.folded_edges >= 4, "{opt:?}");
    assert!(opt.dead_inputs >= 1, "{opt:?}");
    assert!(opt.cse_fanouts >= 3, "{opt:?}");
    assert!(prog.n_ops() < prog_none.n_ops());
    assert!(prog.table_bytes() < prog_none.table_bytes());
    let stream = data::random_code_stream(&ck, 512, 21);
    let want = sim::eval_batch(&net, &stream);
    assert_eq!(engine::run_batch(&prog, &stream), want);
    assert_eq!(engine::run_batch(&prog_none, &stream), want);
    // and through the serving plane (optimized default backend)
    let svc = Service::start(
        Arc::clone(&net),
        ServiceCfg { workers: 2, max_batch: 16, ..Default::default() },
    );
    let mut pending = Vec::new();
    for codes in stream.iter().take(200) {
        pending.push((codes.clone(), svc.submit(codes.clone()).unwrap()));
    }
    for (codes, rx) in pending {
        assert_eq!(rx.recv().unwrap().unwrap().sums, sim::eval(&net, &codes));
    }
    let st = svc.stats();
    assert_eq!(st.opt.as_ref().map(|o| o.ops_after), Some(prog.n_ops()));
    svc.shutdown();
}

#[test]
fn reports_render_end_to_end() {
    // must never panic regardless of which artifacts exist
    let all = report::all(2).unwrap();
    assert!(all.contains("Table 2"));
    assert!(all.contains("Table 3"));
    assert!(all.contains("Table 4"));
    assert!(all.contains("Table 5"));
}

#[test]
fn rl_actor_checkpoint_flow() {
    let Some(ck) = artifact_ckpt("rl_kan_actor") else {
        eprintln!("skipping (run python -m compile.experiments fig7/rl_export)");
        return;
    };
    assert_eq!(ck.dims, vec![17, 6]);
    let tables = lut::from_checkpoint(&ck);
    let net = Netlist::build(&ck, &tables, 2);
    let policy = kanele::rl::NetlistPolicy { ck: &ck, net: &net };
    let reward = kanele::rl::rollout(&policy, 0);
    assert!(reward.is_finite());
    // hardware must comfortably fit the paper's device
    let r = synth::synthesize(&net, &synth::device_by_name("xczu7ev").unwrap());
    assert!(r.fits);
    assert_eq!(r.dsps + r.brams, 0);
}

#[test]
fn testset_loader_rejects_garbage() {
    let dir = std::env::temp_dir().join("kanele_ts_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.json");
    std::fs::write(&p, r#"{"format": "wrong"}"#).unwrap();
    assert!(TestSet::load(&p).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Wire serving (PR 6): the framed TCP front end over the sharded plane.
// ---------------------------------------------------------------------------

/// Synthetic model + running service + wire server on a loopback port.
fn wire_fixture(cfg: ServiceCfg, seed: u64) -> (Arc<Netlist>, Arc<Service>, NetServer) {
    let ck = testutil::synthetic(&[5, 4, 3], &[4, 4, 4], seed);
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let svc = Arc::new(Service::start(Arc::clone(&net), cfg));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let server = NetServer::start(
        Arc::clone(&svc),
        listener,
        NetCfg { levels: 16, ..NetCfg::default() },
    )
    .unwrap();
    (net, svc, server)
}

fn wire_client(server: &NetServer) -> Client {
    let mut c = Client::connect(server.local_addr()).unwrap();
    // every wire test is guarded: a protocol bug must fail an assertion,
    // never hang the suite
    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    c
}

#[test]
fn wire_loopback_bit_exact_and_lifecycle() {
    let (net, svc, mut server) = wire_fixture(
        ServiceCfg {
            workers: 2,
            shards: 2,
            steal: true,
            max_batch: 16,
            max_wait: Duration::from_micros(50),
            queue_depth: 4096,
            ..Default::default()
        },
        2061,
    );
    let mut client = wire_client(&server);
    let mut rng = Rng::new(9);

    // single inferences: wire == direct submit_blocking == sim oracle
    for _ in 0..64 {
        let codes: Vec<u32> = (0..5).map(|_| rng.below(16) as u32).collect();
        let (wire_sums, latency_us) = client.infer(codes.clone()).unwrap();
        assert_eq!(wire_sums, sim::eval(&net, &codes));
        assert_eq!(wire_sums, svc.submit_blocking(codes).unwrap().sums);
        assert!(latency_us >= 0.0);
    }

    // one batch frame: rows come back in order, bit-exact
    let batch: Vec<Vec<u32>> =
        (0..32).map(|_| (0..5).map(|_| rng.below(16) as u32).collect()).collect();
    let rows = client.infer_batch(batch.clone()).unwrap();
    assert_eq!(rows, sim::eval_batch(&net, &batch));

    // malformed width: typed Invalid error frame, connection survives
    match client.infer(vec![1, 2]) {
        Err(NetError::Remote { kind: ErrorKind::Invalid, .. }) => {}
        other => panic!("expected Invalid error frame, got {other:?}"),
    }
    let (sums, _) = client.infer(vec![0; 5]).unwrap();
    assert_eq!(sums.len(), 3);

    // stats frame carries the request shape and live counters
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("input_width").and_then(|v| v.as_i64()), Some(5));
    assert_eq!(stats.get("levels").and_then(|v| v.as_i64()), Some(16));
    assert_eq!(stats.get("shards").and_then(|v| v.as_i64()), Some(2));
    assert!(stats.get("completed").and_then(|v| v.as_i64()).unwrap() >= 64 + 32);

    drop(client);
    server.shutdown();
    let ns = server.stats();
    assert_eq!(ns.accepted, 1);
    assert_eq!(ns.parse_errors, 0);
    assert!(ns.frames_out >= ns.wire_completed);
    svc.shutdown();
}

#[test]
fn wire_backpressure_is_typed_not_a_hang() {
    // workers = 0 parks admission: nothing drains, so a tiny queue fills
    // after exactly queue_depth requests and the next one MUST come back
    // as an immediate backpressure error frame — while the earlier
    // requests are still pending. This is the "clients observe
    // backpressure, never hangs" acceptance criterion on the wire.
    let (_net, svc, mut server) = wire_fixture(
        ServiceCfg { workers: 0, shards: 1, queue_depth: 2, ..Default::default() },
        2062,
    );
    let mut client = wire_client(&server);

    for id in 1..=2u64 {
        let req = WireRequest::Infer { id, model: None, codes: vec![0; 5], deadline_us: None };
        client.send(&req).unwrap();
    }
    let req = WireRequest::Infer { id: 3, model: None, codes: vec![0; 5], deadline_us: None };
    client.send(&req).unwrap();
    // the ONLY frame that can arrive now is the typed rejection of id 3 —
    // ids 1 and 2 are parked in admission with no executor to drain them
    match client.recv_response().unwrap() {
        WireResponse::Error { id: 3, kind: ErrorKind::Backpressure, .. } => {}
        other => panic!("expected backpressure error frame for id 3, got {other:?}"),
    }

    // shutting the service down drops the parked requests' reply senders:
    // the wire surfaces them as typed `dropped` error frames, not silence
    svc.shutdown();
    let mut dropped = std::collections::BTreeSet::new();
    for _ in 0..2 {
        match client.recv_response().unwrap() {
            WireResponse::Error { id, kind: ErrorKind::Dropped, .. } => {
                dropped.insert(id);
            }
            other => panic!("expected dropped error frames, got {other:?}"),
        }
    }
    assert_eq!(dropped.into_iter().collect::<Vec<_>>(), vec![1, 2]);
    server.shutdown();
}

#[test]
fn wire_client_disconnect_mid_request_no_stall() {
    // a client that vanishes with requests in flight must not wedge the
    // plane: its responses are drained server-side and a new connection
    // is served normally
    let (net, svc, mut server) = wire_fixture(
        ServiceCfg {
            workers: 1,
            shards: 1,
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            queue_depth: 4096,
            // stretch execution so the disconnect provably lands while
            // requests are still in flight
            exec_delay: Duration::from_millis(20),
            ..Default::default()
        },
        2063,
    );
    {
        let mut doomed = wire_client(&server);
        for id in 1..=5u64 {
            let req = WireRequest::Infer { id, model: None, codes: vec![1; 5], deadline_us: None };
            doomed.send(&req).unwrap();
        }
        // dropped here: connection closes with all five un-replied
    }
    let mut client = wire_client(&server);
    let codes = vec![2u32; 5];
    let (sums, _) = client.infer(codes.clone()).unwrap();
    assert_eq!(sums, sim::eval(&net, &codes));
    drop(client);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn wire_server_shutdown_drains_in_flight() {
    // graceful drain: shutdown with responses still in flight flushes
    // every admitted request's response before the FIN — the client reads
    // all of them, then a clean EOF
    let (net, svc, mut server) = wire_fixture(
        ServiceCfg {
            workers: 1,
            shards: 1,
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            queue_depth: 4096,
            exec_delay: Duration::from_millis(30),
            ..Default::default()
        },
        2064,
    );
    let mut client = wire_client(&server);
    let mut want = std::collections::BTreeMap::new();
    let mut rng = Rng::new(4);
    for id in 1..=8u64 {
        let codes: Vec<u32> = (0..5).map(|_| rng.below(16) as u32).collect();
        want.insert(id, sim::eval(&net, &codes));
        client.send(&WireRequest::Infer { id, model: None, codes, deadline_us: None }).unwrap();
    }
    // let the reader admit everything (exec_delay keeps the batches
    // themselves in flight well past this), then drain concurrently with
    // the client still reading
    std::thread::sleep(Duration::from_millis(20));
    let reader = std::thread::spawn(move || {
        let mut got = std::collections::BTreeMap::new();
        for _ in 0..8 {
            match client.recv_response().unwrap() {
                WireResponse::Sums { id, sums, .. } => {
                    got.insert(id, sums);
                }
                other => panic!("expected sums during drain, got {other:?}"),
            }
        }
        // after the last in-flight response: clean EOF, not an error
        match client.recv_response() {
            Err(NetError::Frame(kanele::net::FrameError::Closed)) => {}
            other => panic!("expected clean EOF after drain, got {other:?}"),
        }
        got
    });
    server.shutdown();
    let got = reader.join().unwrap();
    assert_eq!(got, want);
    svc.shutdown();
}

#[test]
fn wire_cheetah_control_loop_with_slo() {
    // the §5.7 control loop with the network in it: encode observations
    // locally, evaluate the policy net over TCP, decode actions — bit-exact
    // with the in-process policy, and per-step round trips comfortably
    // inside a generous soft deadline
    let pol_ck = testutil::synthetic(&[rl::OBS_DIM, 8, rl::ACT_DIM], &[5, 5, 5], 0xCA7);
    let tables = lut::from_checkpoint(&pol_ck);
    let pol_net = Arc::new(Netlist::build(&pol_ck, &tables, 2));
    let svc = Arc::new(Service::start(
        Arc::clone(&pol_net),
        ServiceCfg {
            workers: 1,
            shards: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 64,
            ..Default::default()
        },
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let mut server = NetServer::start(
        Arc::clone(&svc),
        listener,
        NetCfg { levels: pol_ck.quantizer(0).levels(), ..NetCfg::default() },
    )
    .unwrap();
    let mut client = wire_client(&server);

    let local = rl::NetlistPolicy { ck: &pol_ck, net: &pol_net };
    let mut env = rl::CheetahLite::new(17);
    let mut obs = env.reset();
    let deadline = Duration::from_millis(50);
    let mut hits = 0usize;
    let steps = 100usize;
    for _ in 0..steps {
        let t0 = std::time::Instant::now();
        let codes = rl::encode_obs(&pol_ck, &obs);
        let (sums, _) = client.infer(codes).unwrap();
        let act = rl::decode_action(&pol_ck, &sums);
        if t0.elapsed() <= deadline {
            hits += 1;
        }
        assert_eq!(act, local.act(&obs), "wire policy diverges from local policy");
        obs = env.step(&act).0;
    }
    // loopback round trips are tens of microseconds; 90% under a 50 ms
    // soft deadline is a deliberately loose bar that still catches hangs,
    // lost frames and pathological queueing
    assert!(hits * 10 >= steps * 9, "only {hits}/{steps} steps met the deadline");

    drop(client);
    server.shutdown();
    svc.shutdown();
}

/// Two-tenant wire fixture: `a` (input width 5, 3 outputs) and `b` (input
/// width 4, 2 outputs) have different geometries, so routing is provable
/// from the response shape alone, not just the values.
fn registry_wire_fixture() -> (Arc<Netlist>, Arc<Netlist>, Arc<Service>, NetServer) {
    let build = |dims: &[usize], seed: u64| {
        let ck = testutil::synthetic(dims, &[4, 4, 4], seed);
        let tables = lut::from_checkpoint(&ck);
        Arc::new(Netlist::build(&ck, &tables, 2))
    };
    let net_a = build(&[5, 4, 3], 2071);
    let net_b = build(&[4, 4, 2], 2072);
    let reg = Arc::new(ModelRegistry::new(engine::OptLevel::default()));
    reg.load("a", Arc::clone(&net_a)).unwrap();
    reg.load("b", Arc::clone(&net_b)).unwrap();
    let svc = Arc::new(Service::start_registry(
        reg,
        ServiceCfg { workers: 2, shards: 2, ..Default::default() },
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let server = NetServer::start(
        Arc::clone(&svc),
        listener,
        NetCfg { levels: 16, ..NetCfg::default() },
    )
    .unwrap();
    (net_a, net_b, svc, server)
}

#[test]
fn wire_multi_tenant_routing_and_pr6_compat() {
    let (net_a, net_b, svc, mut server) = registry_wire_fixture();
    let mut client = wire_client(&server);
    let mut rng = Rng::new(21);

    // named routing is bit-exact per tenant, provable by output width
    for _ in 0..16 {
        let ca: Vec<u32> = (0..5).map(|_| rng.below(16) as u32).collect();
        let cb: Vec<u32> = (0..4).map(|_| rng.below(16) as u32).collect();
        let (sa, _) = client.infer_model(Some("a"), ca.clone()).unwrap();
        let (sb, _) = client.infer_model(Some("b"), cb.clone()).unwrap();
        assert_eq!(sa, sim::eval(&net_a, &ca));
        assert_eq!(sb, sim::eval(&net_b, &cb));
        assert_eq!(sa.len(), 3);
        assert_eq!(sb.len(), 2);
    }
    // batch frames route too
    let batch: Vec<Vec<u32>> =
        (0..8).map(|_| (0..4).map(|_| rng.below(16) as u32).collect()).collect();
    let rows = client.infer_batch_model(Some("b"), batch.clone()).unwrap();
    assert_eq!(rows, sim::eval_batch(&net_b, &batch));

    // a frame with NO model field — a pre-registry client — lands on the
    // default tenant (the first loaded: "a")
    let codes = vec![3u32; 5];
    let (sums, _) = client.infer(codes.clone()).unwrap();
    assert_eq!(sums, sim::eval(&net_a, &codes));

    // unknown model: typed `unsupported` error frame, connection survives
    match client.infer_model(Some("ghost"), vec![0; 5]) {
        Err(NetError::Remote { kind: ErrorKind::Unsupported, msg }) => {
            assert!(msg.contains("ghost"), "msg: {msg}");
        }
        other => panic!("expected Unsupported error frame, got {other:?}"),
    }
    let again = vec![0u32; 5];
    let (sums, _) = client.infer(again.clone()).unwrap();
    assert_eq!(sums, sim::eval(&net_a, &again));

    // stats advertises per-tenant widths for multi-model load generators
    let stats = client.stats().unwrap();
    let models = stats.get("models").and_then(|v| v.as_array()).expect("models array");
    assert_eq!(models.len(), 2);
    let width_of = |name: &str| {
        models
            .iter()
            .find(|m| m.get("name").and_then(|v| v.as_str()) == Some(name))
            .and_then(|m| m.get("input_width"))
            .and_then(|v| v.as_i64())
    };
    assert_eq!(width_of("a"), Some(5));
    assert_eq!(width_of("b"), Some(4));

    drop(client);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn wire_registry_load_unload_swap_under_traffic() {
    let (net_a, _net_b, svc, mut server) = registry_wire_fixture();
    let mut client = wire_client(&server);

    // load a third tenant while the wire serves: the name becomes routable
    // on live connections without reconnecting
    let ck_c = testutil::synthetic(&[6, 3, 2], &[4, 4, 4], 2073);
    let tables = lut::from_checkpoint(&ck_c);
    let net_c = Arc::new(Netlist::build(&ck_c, &tables, 2));
    svc.registry().load("c", Arc::clone(&net_c)).unwrap();
    let cc = vec![1u32; 6];
    let (sums, _) = client.infer_model(Some("c"), cc.clone()).unwrap();
    assert_eq!(sums, sim::eval(&net_c, &cc));

    // a routed swap rewires that tenant only
    let p = net_c.layers[0].neurons[0].luts[0].input;
    let n_codes = 1usize << net_c.layers[0].in_bits;
    client.swap_model(Some("c"), 0, 0, p, vec![777; n_codes]).unwrap();
    let after = svc.registry().resolve_name("c").unwrap().cell().load();
    let (sums, _) = client.infer_model(Some("c"), cc.clone()).unwrap();
    assert_eq!(sums, sim::eval(&after, &cc));
    let ca = vec![1u32; 5];
    let (sa, _) = client.infer_model(Some("a"), ca.clone()).unwrap();
    assert_eq!(sa, sim::eval(&net_a, &ca), "tenant a must be untouched by c's swap");

    // unload: the name stops routing with a typed error frame; the
    // connection and the remaining tenants keep serving
    svc.registry().unload("c").unwrap();
    match client.infer_model(Some("c"), cc) {
        Err(NetError::Remote { kind: ErrorKind::Unsupported, .. }) => {}
        other => panic!("expected Unsupported after unload, got {other:?}"),
    }
    let (sa, _) = client.infer_model(Some("a"), ca.clone()).unwrap();
    assert_eq!(sa, sim::eval(&net_a, &ca));

    drop(client);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn wire_auth_token_gate() {
    // token-gated server: the first frame must be a hello with the secret
    let ck = testutil::synthetic(&[5, 4, 3], &[4, 4, 4], 2074);
    let tables = lut::from_checkpoint(&ck);
    let net = Arc::new(Netlist::build(&ck, &tables, 2));
    let svc = Arc::new(Service::start(
        Arc::clone(&net),
        ServiceCfg { workers: 1, shards: 1, ..Default::default() },
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let mut server = NetServer::start(
        Arc::clone(&svc),
        listener,
        NetCfg { levels: 16, auth_token: Some("s3cret".into()), ..NetCfg::default() },
    )
    .unwrap();

    // no hello at all: typed auth error, then the server closes the socket
    let mut nohello = wire_client(&server);
    match nohello.infer(vec![0; 5]) {
        Err(NetError::Remote { kind: ErrorKind::Auth, .. }) => {}
        other => panic!("expected Auth error frame, got {other:?}"),
    }
    assert!(nohello.infer(vec![0; 5]).is_err(), "connection must close after an auth failure");

    // wrong token: same gate
    let mut wrong = wire_client(&server);
    match wrong.hello(Some("nope")) {
        Err(NetError::Remote { kind: ErrorKind::Auth, .. }) => {}
        other => panic!("expected Auth error frame, got {other:?}"),
    }

    // right token: hello acks and the connection serves bit-exactly
    let mut good = wire_client(&server);
    good.hello(Some("s3cret")).unwrap();
    let codes = vec![1u32; 5];
    let (sums, _) = good.infer(codes.clone()).unwrap();
    assert_eq!(sums, sim::eval(&net, &codes));

    drop(good);
    server.shutdown();
    svc.shutdown();

    // a token-less server acks hello as a no-op, so old and new clients mix
    let (net2, svc2, mut server2) =
        wire_fixture(ServiceCfg { workers: 1, shards: 1, ..Default::default() }, 2075);
    let mut c = wire_client(&server2);
    c.hello(None).unwrap();
    c.hello(Some("anything")).unwrap();
    let codes = vec![0u32; 5];
    let (sums, _) = c.infer(codes.clone()).unwrap();
    assert_eq!(sums, sim::eval(&net2, &codes));
    drop(c);
    server2.shutdown();
    svc2.shutdown();
}
